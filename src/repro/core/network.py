"""The decentralized network: routing workflow + baselines (paper §3.2, Fig 1b/9).

``Network`` wires nodes, the event loop, the credit ledger, gossip, and the
duel-and-judge mechanism together, and supports three deployment modes used
throughout the paper's evaluation (§6.1):

* ``single``        — every node serves only its own users (no cooperation).
* ``centralized``   — an omniscient global dispatcher assigns each arrival to
                      the least-loaded node (upper-bound baseline).
* ``decentralized`` — the WWW.Serve protocol: policy-driven offloading,
                      PoS executor selection, probing, credit transactions,
                      duels, gossip-maintained membership.

Decentralized offload routing itself has two flavors (DESIGN.md
§6.2-gossip), selected by ``routing=``:

* ``gossip`` (default) — rank candidates from the local stale-digest table
  that gossip maintains, discounting each digest by its age; dispatch to
  the top-ranked candidate outright and spend live probes only when the
  top two are too close to call.  Per-request message cost is ~1
  regardless of network size.
* ``probe``           — the pre-gossip behavior: PoS-sample candidates and
  probe each one's live load inline until one accepts (optionally
  power-of-two).  Message cost grows with the probe budget; kept as the
  scaling-bench baseline.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.duel import DuelOutcome, DuelParams, run_duel
from repro.core.gossip import gossip_round
from repro.core.ledger import (CreditChain, CreditOp, LedgerError, SharedLedger)
from repro.core.node import Node, QueuedRequest
from repro.core.pos import pos_sample, pos_sample_one
from repro.obs import MetricsRegistry, get_tracer
from repro.sim.events import EventLoop
from repro.sim.executor import digest_staleness_weight, prefix_fingerprint_id
from repro.sim.metrics import CompletedRequest, MetricsCollector
from repro.sim.servicemodel import (DIGEST_PRESSURE_PRIOR, DIGEST_TIE_EPS,
                                    KV_BYTES_PER_TOKEN, TRANSFER_BYTES_PER_S,
                                    TRANSFER_EMA_BETA)
from repro.sim.workload import Request

TREASURY = "__treasury__"


def _mix_pressure(prefill_headroom: float, decode_headroom: float,
                  expected_tokens_per_step: float, req: Request) -> float:
    """Phase-mix pressure formula shared by live probes and gossip digests:
    each phase's occupancy weighted by the request's token mix, decode
    occupancy discounted by the speculative turnover factor."""
    total = max(1, req.prompt_tokens + req.output_tokens)
    wp = req.prompt_tokens / total
    return (wp * (1.0 - prefill_headroom)
            + (1.0 - wp) * (1.0 - decode_headroom)
            / expected_tokens_per_step)


@dataclass
class _DuelState:
    duel_id: str
    req: Request
    origin: str
    executors: Tuple[str, str]
    finished: List[str] = field(default_factory=list)
    user_served: bool = False
    judges_done: int = 0
    judges: Tuple[str, ...] = ()


class Network:
    def __init__(self, mode: str = "decentralized", *, seed: int = 0,
                 ledger_mode: str = "shared", msg_latency: float = 0.05,
                 duel: Optional[DuelParams] = None,
                 gossip_interval: float = 1.0, gossip_fanout: int = 2,
                 suspect_after: float = 5.0,
                 init_balance: float = 20.0,
                 restake_interval: Optional[float] = 30.0,
                 restake_fraction: float = 0.5,
                 max_probes: int = 3,
                 power_of_two: bool = False,
                 routing: str = "gossip",
                 cache_affinity: bool = True,
                 registry: Optional[MetricsRegistry] = None) -> None:
        assert mode in ("single", "centralized", "decentralized")
        assert ledger_mode in ("shared", "chain")
        assert routing in ("gossip", "probe")
        self.mode = mode
        self.routing = routing
        self.ledger_mode = ledger_mode
        self.loop = EventLoop()
        self.rng = np.random.default_rng(seed)
        self.nodes: Dict[str, Node] = {}
        self.metrics = MetricsCollector()
        self.duel_params = duel or DuelParams()
        self.msg_latency = msg_latency
        self.gossip_interval = gossip_interval
        self.gossip_fanout = gossip_fanout
        self.suspect_after = suspect_after
        self.init_balance = init_balance
        self.restake_interval = restake_interval
        self.restake_fraction = restake_fraction
        self.max_probes = max_probes
        self.power_of_two = power_of_two
        # cache-affinity dispatch (DESIGN.md §6.1-prefix): among near-tied
        # gossip leaders, prefer nodes whose digest advertises the request's
        # shared prefix as resident — a pressure tie is not a real tie when
        # one node can skip most of the prefill
        self.cache_affinity = cache_affinity

        self.shared_ledger = SharedLedger()
        self.chains: Dict[str, CreditChain] = {}
        self._duels: Dict[str, _DuelState] = {}
        self._duel_ctr = itertools.count()
        self.credit_trace: List[Tuple[float, str, float]] = []  # (t, node, credit)
        self.block_confirmations: List[int] = []
        self._shutdown = False
        # per-node observed KV-transfer rate (disagg handoffs), learned from
        # ExecutorLoad.handoff_bytes deltas; seeded with the static link
        # constant so routing is unchanged until observations arrive
        self._transfer_rate_ema: Dict[str, float] = {}
        self._transfer_obs: Dict[str, Tuple[float, int]] = {}
        # message accounting (DESIGN.md §6.2-gossip): "probe" counts live
        # load round-trips, "dispatch" delegated hand-offs, "bounce"
        # delivery-time declines, "gossip" per-round view exchanges,
        # "dropped" queued requests lost to churn/shutdown drains,
        # "giveup" offload attempts that found every candidate saturated
        # (DESIGN.md §Observability).  The scaling bench derives routing
        # messages-per-request from these; every increment also feeds the
        # labeled ``repro.obs`` registry so snapshots stay auditable.
        self.msg_counts: Dict[str, int] = {
            "probe": 0, "dispatch": 0, "bounce": 0, "gossip": 0,
            "dropped": 0, "giveup": 0}
        self.registry = registry if registry is not None \
            else MetricsRegistry()

        # seed the treasury that funds duel bonuses / judge fees
        self._apply_ops([CreditOp("mint", "", TREASURY, 1e9)], proposer=None)

    # ------------------------------------------------------------- membership
    def add_node(self, node: Node) -> None:
        node.network = self
        node.bind_executor(self.loop)
        self.nodes[node.id] = node
        if self.ledger_mode == "chain":
            chain = CreditChain(node.id)
            donors = [c for c in self.chains.values() if c.blocks]
            if donors:
                # bootstrap: replay history from the longest live chain
                donor = max(donors, key=lambda c: len(c.blocks))
                for blk in donor.blocks:
                    chain.append(blk)
            else:
                # first chain: write the treasury genesis block
                genesis = chain.propose(
                    [CreditOp("mint", "", TREASURY, 1e9)], self.loop.now,
                    node.secret if hasattr(node, "secret") else b"sys")
                chain.append(genesis)
            self.chains[node.id] = chain
        ops = [CreditOp("mint", "", node.id, self.init_balance + node.policy.stake),
               CreditOp("stake", node.id, "", node.policy.stake)]
        self._apply_ops(ops, proposer=node.id)
        # introduce to the network: one gossip exchange with an online peer
        for other in self.nodes.values():
            if other is not node and other.online:
                gossip_round(node.view, other.view)
                break

    # ----------------------------------------------------------------- ledger
    def _apply_ops(self, ops: Sequence[CreditOp], proposer: Optional[str]) -> None:
        if self.ledger_mode == "shared" or proposer is None or not self.chains:
            try:
                self.shared_ledger.apply(ops)
            except LedgerError:
                pass  # e.g. slashing an already-empty stake: drop the op set
            return
        # full-chain path: proposer builds + signs a block, broadcasts, and the
        # block finalizes once a majority of ONLINE peers validate + append.
        # Offline peers miss the broadcast and resync on rejoin (below).
        chain = self.chains[proposer]
        node = self.nodes.get(proposer)
        secret = node.secret if node else b"sys"
        block = chain.propose(ops, self.loop.now, secret)
        peers = {nid: c for nid, c in self.chains.items()
                 if nid not in self.nodes or self.nodes[nid].online}
        confirms = sum(1 for c in peers.values() if c.validate(block)[0])
        self.block_confirmations.append(confirms)
        if confirms * 2 > len(peers):
            for peer_chain in peers.values():
                try:
                    peer_chain.append(block)
                except LedgerError:
                    pass
            # mirror into the shared view so balance reads stay O(1)
            try:
                self.shared_ledger.apply(ops)
            except LedgerError:
                pass

    def resync_chain(self, node_id: str) -> int:
        """Catch a rejoining node's chain up from the longest live chain
        (paper: 'newly joined resources can be quickly integrated').
        Returns the number of blocks replayed."""
        if self.ledger_mode != "chain" or node_id not in self.chains:
            return 0
        mine = self.chains[node_id]
        donors = [c for nid, c in self.chains.items()
                  if nid != node_id
                  and (nid not in self.nodes or self.nodes[nid].online)]
        if not donors:
            return 0
        donor = max(donors, key=lambda c: len(c.blocks))
        replayed = 0
        for blk in donor.blocks[len(mine.blocks):]:
            try:
                mine.append(blk)
                replayed += 1
            except LedgerError:
                break
        return replayed

    def ledger_balance(self, node_id: str) -> float:
        return self.shared_ledger.balance_of(node_id)

    def ledger_stakes(self) -> Dict[str, float]:
        return self.shared_ledger.stakes()

    # ------------------------------------------------------- event accounting
    def _count_msg(self, kind: str, n: int = 1) -> None:
        self.msg_counts[kind] += n
        self.registry.counter("net.messages", kind=kind).inc(n)

    def _count_dropped(self, reason: str) -> None:
        """A queued request fell out of a queue (churn rerouting, or a
        shutdown drain with nobody online).  Previously invisible; the
        event feeds both the ``dropped`` key next to ``msg_counts`` and a
        labeled registry counter so SLO denominators can be audited
        against completions end to end (DESIGN.md §Observability)."""
        self.msg_counts["dropped"] += 1
        self.registry.counter("net.dropped", reason=reason).inc()

    def _count_giveup(self, path: str) -> None:
        """An offload attempt found every candidate saturated or burned
        its probe budget; the request falls back to the origin's local
        queue.  Counted so 'how often did routing fail to help' is a
        first-class number rather than a diff of other counters."""
        self.msg_counts["giveup"] += 1
        self.registry.counter("net.giveup", path=path).inc()

    # -------------------------------------------------------------- workflow
    def submit(self, req: Request) -> None:
        if self.mode == "centralized":
            self._dispatch_centralized(req)
        else:
            self.nodes[req.origin].submit(req)

    def resubmit_elsewhere(self, req: Request,
                           enqueued_at: Optional[float] = None) -> None:
        """Re-target ``req`` at a random online node (churn rerouting).

        ``enqueued_at`` is the request's *original* enqueue time, preserved
        across the re-enqueue so ``queue_wait`` keeps counting the time
        already spent queued at the node that dropped it.
        """
        enq = self.loop.now if enqueued_at is None else enqueued_at
        online = [n for n in self.nodes.values() if n.online]
        if not online:
            if self._shutdown:
                self._count_dropped("shutdown")
                return   # draining with nobody online: drop, don't spin
            self.loop.schedule(5.0,
                               lambda: self.resubmit_elsewhere(req, enq))
            return
        pick = online[int(self.rng.integers(len(online)))]
        tr = get_tracer()
        if tr.enabled:
            tr.event("route.decide", req.rid, pick.id, self.loop.now,
                     mode=self.mode, outcome="resubmit")
        # executing another node's traffic is delegation even when it got
        # here via churn rerouting: keep the flag (and the credit transfer
        # at completion) truthful
        pick.enqueue(QueuedRequest(req, enq,
                                   delegated=pick.id != req.origin,
                                   origin_node=req.origin))

    def _est_wait(self, node: Node, req: Request) -> float:
        """Omniscient load estimate for the centralized baseline, built from
        the executor's load snapshot (queued + in-flight token backlog in
        both phases).  A speculative backend's decode backlog drains
        ``expected_tokens_per_step`` times faster per target forward
        (DESIGN.md §6.1-spec), so its effective decode capacity is scaled
        by the acceptance model the load report carries (>= 1 by
        construction; 1.0 on non-speculative backends).  This treats a
        verify forward as costing one decode forward — the draft's own
        overhead is charged by the ``executor.estimate`` term below, which
        both spec executors fold it into."""
        ld = node.executor.load()
        backlog = sum(q.req.output_tokens for q in
                      node.local_queue + node.delegated_queue)
        backlog += ld.pending_decode_tokens
        cap = (node.profile.decode_tps * node.profile.saturation
               * ld.expected_tokens_per_step)
        est = (backlog / cap
               + ld.pending_prefill_tokens / node.profile.prefill_tps
               + node.executor.estimate(req.prompt_tokens,
                                        req.output_tokens))
        # disagg backends queue this request's prefilled KV behind the
        # handoffs already on the wire; charge them at the node's LEARNED
        # transfer rate rather than the static link constant
        rate = self._observe_transfer_rate(node.id, self.loop.now,
                                           ld.handoff_bytes)
        if ld.transfer_inflight > 0:
            est += (ld.transfer_inflight * req.prompt_tokens
                    * KV_BYTES_PER_TOKEN / rate)
        return est

    def _observe_transfer_rate(self, nid: str, t: float,
                               handoff_bytes: int) -> float:
        """Per-node EMA of the observed KV handoff rate (DESIGN.md
        §6.1-disagg): every sighting of a node's load — an omniscient
        ``_est_wait`` read, a live probe, or a gossip digest stamped with
        its origin time ``t`` — exposes cumulative ``handoff_bytes``, so
        the bytes moved between two sightings over the elapsed sim time is
        a direct throughput sample of that node's actual link, which the
        static ``TRANSFER_BYTES_PER_S`` model cannot see.  Zero-byte
        windows are skipped (an idle link is not a slow link), and samples
        older than the last recorded sighting are ignored (a stale digest
        arriving after a fresh probe must not rewind the baseline)."""
        rate = self._transfer_rate_ema.get(nid, TRANSFER_BYTES_PER_S)
        last = self._transfer_obs.get(nid)
        if last is not None and t <= last[0]:
            return rate
        self._transfer_obs[nid] = (t, handoff_bytes)
        if last is not None:
            dt = t - last[0]
            db = handoff_bytes - last[1]
            if db > 0:
                rate += TRANSFER_EMA_BETA * (db / dt - rate)
                self._transfer_rate_ema[nid] = rate
        return rate

    def _phase_pressure(self, node: Node, req: Request) -> float:
        """Phase-aware load score in [0, 1]: each phase's KV occupancy
        weighted by the request's token mix, so prompt-heavy requests chase
        prefill headroom and decode-heavy requests chase decode headroom
        (DESIGN.md §6.1-disagg).  For colocated backends both headrooms
        collapse to ``kv_headroom`` and this reduces to plain KV pressure.

        The decode term is discounted by the backend's
        ``expected_tokens_per_step`` (DESIGN.md §6.1-spec; >= 1 by
        construction, 1.0 on non-speculative backends): the same KV
        occupancy on a speculation-enabled node turns over
        acceptance-model-times faster, so decode-heavy requests chase
        spec-enabled nodes before equally-occupied plain ones.  Draft
        overhead is deliberately ignored here — pressure ranks occupancy,
        and the overhead is second-order next to the E-fold turnover.
        """
        ld = node.executor.load()
        return _mix_pressure(ld.prefill_headroom, ld.decode_headroom,
                             ld.expected_tokens_per_step, req)

    def _probe_pressure(self, node: Node, req: Request) -> float:
        """A *live* load probe: one request/response round-trip on the wire
        (counted in ``msg_counts``), whose response also carries a fresh
        ``handoff_bytes`` sample for the transfer-rate EMA."""
        self._count_msg("probe")
        ld = node.executor.load()
        self._observe_transfer_rate(node.id, self.loop.now, ld.handoff_bytes)
        return _mix_pressure(ld.prefill_headroom, ld.decode_headroom,
                             ld.expected_tokens_per_step, req)

    def _digest_pressure(self, origin: Node, nid: str, req: Request) -> float:
        """Pressure inferred for ``nid`` from ``origin``'s gossip-learned
        digest table, with no message sent (DESIGN.md §6.2-gossip).  The
        digest's raw pressure is discounted toward the neutral prior by
        its age; a peer with no digest yet scores exactly the prior.  The
        digest's ``handoff_bytes`` doubles as a transfer-rate observation
        stamped at its origin time."""
        d = origin.view.digest_of(nid)
        if d is None:
            return DIGEST_PRESSURE_PRIOR
        self._observe_transfer_rate(nid, d.t, d.handoff_bytes)
        raw = _mix_pressure(d.prefill_headroom, d.decode_headroom,
                            d.expected_tokens_per_step, req)
        w = digest_staleness_weight(self.loop.now - d.t)
        return w * raw + (1.0 - w) * DIGEST_PRESSURE_PRIOR

    def _dispatch_centralized(self, req: Request,
                              enqueued_at: Optional[float] = None) -> None:
        enq = self.loop.now if enqueued_at is None else enqueued_at
        online = [n for n in self.nodes.values() if n.online]
        if not online:
            if self._shutdown:
                self._count_dropped("shutdown")
                return   # draining with nobody online: drop, don't spin
            self.loop.schedule(
                5.0, lambda: self._dispatch_centralized(req, enq))
            return
        best = min(online, key=lambda n: self._est_wait(n, req))
        delegated = best.id != req.origin
        lat = self.msg_latency if delegated else 0.0
        tr = get_tracer()
        if tr.enabled:
            tr.span("route.decide", req.rid, req.origin, enq,
                    self.loop.now + lat, mode="centralized",
                    outcome="dispatch" if delegated else "local",
                    target=best.id)
        self.loop.schedule(lat, lambda: best.enqueue(
            QueuedRequest(req, enq, delegated=delegated,
                          origin_node=req.origin)))

    # -- decentralized offload (paper Fig 9 step 3.2): digest-table ranking
    # (routing="gossip", DESIGN.md §6.2-gossip) or PoS sampling + live
    # probing (routing="probe") --
    def try_offload(self, origin: Node, req: Request,
                    enqueued_at: Optional[float] = None) -> bool:
        stakes = self.ledger_stakes()
        eligible = [p for p in origin.view.online_peers()
                    if p in self.nodes and self.nodes[p].online]
        if not eligible:
            return False
        if self.rng.random() < self.duel_params.p_d and len(eligible) >= 2:
            return self._start_duel(origin, req, stakes, eligible)
        if self.routing == "gossip":
            return self._offload_gossip(origin, req, eligible, stakes,
                                        enqueued_at)
        return self._offload_probe(origin, req, eligible, stakes, enqueued_at)

    def _offload_gossip(self, origin: Node, req: Request,
                        eligible: Sequence[str], stakes: Dict[str, float],
                        enqueued_at: Optional[float]) -> bool:
        """Digest-table routing (DESIGN.md §6.2-gossip): rank every known
        peer by staleness-discounted pressure at zero message cost.

        * Every candidate at/above saturation pressure → give up without a
          single message (the probe path would burn its whole probe budget
          discovering the same thing).
        * Best pressure in the *contended or unknown* region (>= the
          neutral prior) with a near-tie → the stale table can't be
          trusted to pick: probe the top two live and take the better
          accepting one (this is also the cold-start path, since peers
          with no digest yet score exactly the prior).
        * Otherwise — gossip recently showed clear headroom — dispatch
          outright with zero probes, picking stake-weighted among the
          near-tied leaders (PoS incentive + herd avoidance); the receiver
          applies its acceptance policy at delivery and bounces declines.
        """
        scored = sorted((self._digest_pressure(origin, nid, req), nid)
                        for nid in eligible)
        best_pr = scored[0][0]
        if best_pr >= 1.0:
            self._count_giveup("gossip")
            return False
        enq = self.loop.now if enqueued_at is None else enqueued_at
        near = [nid for pr, nid in scored if pr - best_pr < DIGEST_TIE_EPS]
        if best_pr >= DIGEST_PRESSURE_PRIOR and len(near) >= 2:
            # contended and too close to call from stale digests: probe the
            # top two live — prefix-warm near-tied peers first, so an exact
            # live-pressure tie resolves toward the cache (§6.1-prefix)
            probe_order = (self._affinity_filter(origin, req, near)
                           + [nid for _pr, nid in scored])
            seen: set = set()
            top2 = [nid for nid in probe_order
                    if not (nid in seen or seen.add(nid))][:2]
            best = None
            for nid in top2:
                cand = self.nodes[nid]
                live = self._probe_pressure(cand, req)
                if (cand.online and live < 1.0
                        and cand.policy.accepts_delegated(
                            cand.n_active, cand.profile.saturation,
                            len(cand.delegated_queue), self.rng)
                        and (best is None or live < best[0])):
                    best = (live, cand)
            if best is None:
                self._count_giveup("gossip")
                return False
            pick = best[1]
            self._count_msg("dispatch")
            delay = 2 * self.msg_latency + self.msg_latency
            tr = get_tracer()
            if tr.enabled:
                tr.span("route.decide", req.rid, origin.id, enq,
                        self.loop.now + delay, mode="gossip",
                        outcome="probe", target=pick.id, probed=top2,
                        pressure=round(best[0], 4),
                        candidates=[[nid, round(pr, 4)]
                                    for pr, nid in scored[:3]])
            self.loop.schedule(delay, lambda: pick.enqueue(
                QueuedRequest(req, enq, delegated=True,
                              origin_node=origin.id)))
            return True
        full = near
        near = self._affinity_filter(origin, req, near)
        pick_id = pos_sample_one(stakes, near, self.rng)
        if pick_id is None:
            return False
        pick = self.nodes[pick_id]
        self._count_msg("dispatch")
        tr = get_tracer()
        if tr.enabled:
            d = origin.view.digest_of(pick_id)
            tr.span("route.decide", req.rid, origin.id, enq,
                    self.loop.now + self.msg_latency, mode="gossip",
                    outcome="dispatch", target=pick_id,
                    pressure=round(best_pr, 4),
                    staleness=(round(self.loop.now - d.t, 4)
                               if d is not None else None),
                    affinity=len(near) < len(full),
                    candidates=[[nid, round(pr, 4)]
                                for pr, nid in scored[:3]])
        self.loop.schedule(self.msg_latency, lambda: self._deliver_offload(
            pick, QueuedRequest(req, enq, delegated=True,
                                origin_node=origin.id)))
        return True

    def _affinity_filter(self, origin: Node, req: Request,
                         near: List[str]) -> List[str]:
        """Cache-affinity tie-break (DESIGN.md §6.1-prefix): when several
        near-tied leaders exist and the request names a shared prefix,
        narrow the stake-weighted draw to peers whose gossip digest lists
        that prefix's fingerprint as resident — they can serve most of the
        prompt from cached pages.  Pressure stays the primary signal: this
        only breaks ties, never overrides a clearly less-loaded peer, and
        falls back to the full near-tie set when no digest advertises the
        prefix (or affinity is disabled)."""
        if (not self.cache_affinity or req.prefix_id is None
                or len(near) < 2):
            return near
        fp = prefix_fingerprint_id(req.prefix_id)
        warm = []
        for nid in near:
            d = origin.view.digest_of(nid)
            if d is not None and fp in d.resident_prefixes:
                warm.append(nid)
        return warm or near

    def _deliver_offload(self, cand: Node, qr: QueuedRequest) -> None:
        """Delivery of an optimistically-dispatched offload (gossip
        routing): the probe path consulted the acceptance policy before
        dispatching, so here the *receiving* node applies it at delivery
        time instead, bouncing declines back to the origin (offline
        candidates bounce through the usual churn path inside
        ``enqueue``).  The bounce preserves the original enqueue time."""
        if cand.online and not cand.policy.accepts_delegated(
                cand.n_active, cand.profile.saturation,
                len(cand.delegated_queue), self.rng):
            self._count_msg("bounce")
            tr = get_tracer()
            if tr.enabled:
                tr.event("route.decide", qr.req.rid, cand.id,
                         self.loop.now, mode=self.mode, outcome="bounce")
            origin = self.nodes.get(qr.origin_node)
            if origin is not None and origin.online:
                origin.enqueue(QueuedRequest(qr.req, qr.enqueue_time,
                                             delegated=False,
                                             origin_node=qr.origin_node))
            else:
                self.resubmit_elsewhere(qr.req, enqueued_at=qr.enqueue_time)
            return
        cand.enqueue(qr)

    def _offload_probe(self, origin: Node, req: Request,
                       eligible: Sequence[str], stakes: Dict[str, float],
                       enqueued_at: Optional[float]) -> bool:
        probes = 0
        tried: List[str] = []
        while probes < self.max_probes:
            if self.power_of_two:
                # BEYOND-PAPER: power-of-two-choices on top of PoS — sample
                # two candidates by stake, probe both, pick the less loaded
                # *for this request's phase mix* (prompt-heavy requests chase
                # prefill headroom, decode-heavy ones decode headroom).
                # Keeps PoS incentives (both draws are stake-weighted) while
                # exploiting the probe the protocol already pays for.
                pair = pos_sample(stakes, eligible, 2, self.rng,
                                  exclude=tried)
                if not pair:
                    break
                pressure = {n: self._probe_pressure(self.nodes[n], req)
                            for n in pair}
                pair.sort(key=lambda n: (pressure[n],
                                         self.nodes[n].utilization()))
                cand_id = pair[0]
                probes += 1
                tried.extend(pair)
            else:
                cand_id = pos_sample_one(stakes, eligible, self.rng,
                                         exclude=tried)
                if cand_id is None:
                    break
                probes += 1
                tried.append(cand_id)
                pressure = {cand_id: self._probe_pressure(
                    self.nodes[cand_id], req)}
            cand = self.nodes[cand_id]
            # a probe response exposing zero headroom for this request's
            # phase mix is a decline — keep probing (the request would only
            # sit in the candidate's queue behind the saturated phase)
            if (cand.online
                    and pressure[cand_id] < 1.0
                    and cand.policy.accepts_delegated(
                        cand.n_active, cand.profile.saturation,
                        len(cand.delegated_queue), self.rng)):
                self._count_msg("dispatch")
                enq = self.loop.now if enqueued_at is None else enqueued_at
                delay = 2 * self.msg_latency * probes + self.msg_latency
                tr = get_tracer()
                if tr.enabled:
                    tr.span("route.decide", req.rid, origin.id, enq,
                            self.loop.now + delay, mode="probe",
                            outcome="dispatch", target=cand_id,
                            probes=probes,
                            pressure=round(pressure[cand_id], 4))
                self.loop.schedule(delay, lambda cand=cand: cand.enqueue(
                    QueuedRequest(req, enq, delegated=True,
                                  origin_node=origin.id)))
                return True
        self._count_giveup("probe")
        return False

    @property
    def routing_messages(self) -> int:
        """Total routing-plane messages so far: two per live probe
        (request + response), one per delegated dispatch, one per bounce.
        Gossip-plane traffic is accounted separately in
        ``msg_counts["gossip"]``."""
        c = self.msg_counts
        return 2 * c["probe"] + c["dispatch"] + c["bounce"]

    def on_queued_dropped(self, node: Node, qr: QueuedRequest) -> None:
        """A node went offline with ``qr`` still queued (never admitted).

        Plain user traffic is resubmitted to an online peer.  Duel legs are
        instead marked finished-without-response so the duel still resolves,
        and judge evaluations are counted done — resubmitting either would
        double-record the user request or run a judge against the wrong
        model.
        """
        self._count_dropped("offline")
        tr = get_tracer()
        if tr.enabled:
            tr.event("route.drop", qr.req.rid, node.id, self.loop.now,
                     duel=qr.duel_id is not None)
        if qr.duel_id is None:
            self.resubmit_elsewhere(qr.req, enqueued_at=qr.enqueue_time)
            return
        if qr.duel_id.endswith(":judging"):
            st = self._duels.get(qr.duel_id.rsplit(":", 1)[0])
            if st is not None:
                self._on_judge_done(st)
            return
        st = self._duels.get(qr.duel_id)
        if st is not None:
            st.finished.append(node.id)
            if len(st.finished) == 2:
                if not st.user_served:
                    # both legs lost to churn: nobody will ever respond, so
                    # the user's request re-enters the network as plain work
                    st.user_served = True
                    self.resubmit_elsewhere(st.req)
                self._dispatch_judges(st)

    def _start_duel(self, origin: Node, req: Request, stakes: Dict[str, float],
                    eligible: Sequence[str]) -> bool:
        execs = pos_sample(stakes, eligible, 2, self.rng)
        if len(execs) < 2:
            return False
        accepted = []
        for eid in execs:
            e = self.nodes[eid]
            if e.online and e.policy.accepts_delegated(
                    e.n_active, e.profile.saturation,
                    len(e.delegated_queue), self.rng):
                accepted.append(eid)
        if len(accepted) < 2:
            return False
        did = f"duel-{next(self._duel_ctr)}"
        self._duels[did] = _DuelState(did, req, origin.id,
                                      (accepted[0], accepted[1]))
        for i, eid in enumerate(accepted):
            e = self.nodes[eid]
            delay = 3 * self.msg_latency
            self.loop.schedule(delay, lambda e=e, i=i: e.enqueue(
                QueuedRequest(req, self.loop.now, delegated=True,
                              origin_node=origin.id, duel_id=did)))
        return True

    # ------------------------------------------------------------ completion
    @staticmethod
    def _timings(qr: QueuedRequest) -> Tuple[float, float]:
        """(ttft, queue_wait) from the executor's completion timestamps."""
        nan = float("nan")
        ttft = (qr.first_token_at - qr.req.arrival
                if qr.first_token_at is not None else nan)
        wait = (qr.started_at - qr.enqueue_time
                if qr.started_at is not None else nan)
        return ttft, wait

    def on_request_finished(self, executor: Node, qr: QueuedRequest) -> None:
        now = self.loop.now
        ttft, queue_wait = self._timings(qr)
        if qr.duel_id is not None:
            if qr.duel_id.endswith(":judging"):
                self.metrics.record(CompletedRequest(
                    rid=qr.req.rid, origin=qr.origin_node, executor=executor.id,
                    arrival=qr.req.arrival, finish=now, slo_s=qr.req.slo_s,
                    delegated=True, is_duel_extra=True,
                    ttft=ttft, queue_wait=queue_wait))
                st = self._duels.get(qr.duel_id.rsplit(":", 1)[0])
                if st is not None:
                    self._on_judge_done(st)
                return
            self._on_duel_response(executor, qr)
            return
        finish = now + (self.msg_latency if qr.delegated else 0.0)
        tr = get_tracer()
        if tr.enabled and qr.delegated:
            # the response transit back to the origin — the last leg of
            # the request's latency partition (DESIGN.md §Observability)
            tr.span("route.return", qr.req.rid, executor.id, now, finish)
        self.metrics.record(CompletedRequest(
            rid=qr.req.rid, origin=qr.origin_node, executor=executor.id,
            arrival=qr.req.arrival, finish=finish, slo_s=qr.req.slo_s,
            delegated=qr.delegated, is_duel_extra=qr.req.is_duel_extra,
            ttft=ttft, queue_wait=queue_wait))
        if qr.delegated and not qr.req.is_duel_extra:
            price = self.nodes[qr.origin_node].policy.offload_price \
                if qr.origin_node in self.nodes else 1.0
            self._apply_ops(
                [CreditOp("transfer", qr.origin_node, executor.id, price,
                          ref=qr.req.rid)], proposer=executor.id)

    def _on_duel_response(self, executor: Node, qr: QueuedRequest) -> None:
        st = self._duels.get(qr.duel_id)
        if st is None:
            return
        st.finished.append(executor.id)
        ttft, queue_wait = self._timings(qr)
        if not st.user_served:
            # the first response back serves the user
            st.user_served = True
            self.metrics.record(CompletedRequest(
                rid=st.req.rid, origin=st.origin, executor=executor.id,
                arrival=st.req.arrival, finish=self.loop.now + self.msg_latency,
                slo_s=st.req.slo_s, delegated=True, is_duel_extra=False,
                ttft=ttft, queue_wait=queue_wait))
            price = self.nodes[st.origin].policy.offload_price \
                if st.origin in self.nodes else 1.0
            self._apply_ops([CreditOp("transfer", st.origin, executor.id,
                                      price, ref=st.req.rid)],
                            proposer=executor.id)
        else:
            # challenger inference: counts as duel overhead (paper §7.1)
            self.metrics.record(CompletedRequest(
                rid=f"{st.req.rid}-challenger", origin=st.origin,
                executor=executor.id, arrival=st.req.arrival,
                finish=self.loop.now, slo_s=st.req.slo_s,
                delegated=True, is_duel_extra=True))
        if len(st.finished) == 2:
            self._dispatch_judges(st)

    def _dispatch_judges(self, st: _DuelState) -> None:
        stakes = self.ledger_stakes()
        eligible = [n for n, node in self.nodes.items()
                    if node.online and n not in st.executors and n != st.origin]
        judges = pos_sample(stakes, eligible, self.duel_params.k_judges, self.rng)
        if not judges:
            self._resolve_duel(st, ())
            return
        st.judges = tuple(judges)
        for j in judges:
            node = self.nodes[j]
            eval_req = Request(
                rid=f"{st.duel_id}-judge-{j}", origin=j, arrival=self.loop.now,
                prompt_tokens=st.req.prompt_tokens + 2 * st.req.output_tokens,
                output_tokens=64, slo_s=st.req.slo_s, is_duel_extra=True)
            jqr = QueuedRequest(eval_req, self.loop.now, delegated=True,
                                origin_node=st.origin)
            jqr.duel_id = f"{st.duel_id}:judging"
            node.enqueue(jqr)

    def _on_judge_done(self, st: _DuelState) -> None:
        st.judges_done += 1
        if st.judges_done >= len(st.judges):
            self._resolve_duel(st, st.judges)

    def _resolve_duel(self, st: _DuelState, judges: Sequence[str]) -> None:
        q = {nid: n.quality for nid, n in self.nodes.items()}
        out = run_duel(st.duel_id, st.executors[0], st.executors[1], judges, q,
                       self.duel_params, self.rng, treasury=TREASURY)
        self._apply_ops(out.ops, proposer=out.winner)
        if out.winner in self.nodes:
            self.nodes[out.winner].duel_wins += 1
        if out.loser in self.nodes:
            self.nodes[out.loser].duel_losses += 1
        del self._duels[st.duel_id]

    # -------------------------------------------------------- periodic tasks
    def _rebalance_tick(self, interval: float) -> None:
        """Re-examine overloaded queues (paper: offload once workload exceeds
        threshold — not only at admission time)."""
        if self._shutdown:
            return
        for node in self.nodes.values():
            if not node.online:
                continue
            moved = 0
            while (node.local_queue and moved < 4
                   and node.policy.wants_offload(node.queue_len, node.n_active,
                                                 node.profile.saturation,
                                                 self.ledger_balance(node.id),
                                                 self.rng)):
                qr = node.local_queue.pop()      # youngest queued local request
                # the request keeps its original enqueue time through the
                # move: queue_wait must count the time already spent here
                if self.try_offload(node, qr.req,
                                    enqueued_at=qr.enqueue_time):
                    moved += 1
                else:
                    node.local_queue.append(qr)
                    break
        self.loop.schedule(interval, lambda: self._rebalance_tick(interval))

    def _gossip_tick(self) -> None:
        if self._shutdown:
            return
        for node in self.nodes.values():
            if not node.online:
                continue
            # heartbeat with a fresh load digest piggybacked on the
            # membership record (DESIGN.md §6.2-gossip)
            node.publish_digest(self.loop.now)
            peers = [p for p in node.view.online_peers() if p in self.nodes]
            if peers:
                picks = self.rng.choice(len(peers),
                                        size=min(self.gossip_fanout, len(peers)),
                                        replace=False)
                for i in picks:
                    peer = self.nodes[peers[int(i)]]
                    if peer.online:
                        gossip_round(node.view, peer.view)
                        self._count_msg("gossip", 2)    # push + pull
            node.view.suspect_failures(self.loop.now, self.suspect_after)
        self.loop.schedule(self.gossip_interval, self._gossip_tick)

    def _restake_tick(self) -> None:
        """Assumption 5.4: rational nodes re-stake a fraction of earnings —
        and unstake when too illiquid to pay for offloading."""
        if self._shutdown:
            return
        reserve = 5.0
        for node in self.nodes.values():
            if not node.online:
                continue
            bal = self.ledger_balance(node.id)
            stake = self.shared_ledger.stake_of(node.id)
            free = bal - reserve           # keep an offload reserve liquid
            if free > 0.1:
                amt = self.restake_fraction * free
                self._apply_ops([CreditOp("stake", node.id, "", amt)],
                                proposer=node.id)
            elif bal < reserve and stake > node.policy.stake:
                amt = min(stake - node.policy.stake, 4.0 * reserve)
                self._apply_ops([CreditOp("unstake", node.id, "", amt)],
                                proposer=node.id)
        self.loop.schedule(self.restake_interval, self._restake_tick)

    def _trace_tick(self, interval: float) -> None:
        if self._shutdown:
            return
        for node in self.nodes.values():
            credit = (self.ledger_balance(node.id)
                      + self.shared_ledger.stake_of(node.id))
            self.credit_trace.append((self.loop.now, node.id, credit))
        self.loop.schedule(interval, lambda: self._trace_tick(interval))

    # -------------------------------------------------------------- execution
    def run(self, requests: Sequence[Request], until: float,
            trace_interval: Optional[float] = 10.0,
            rebalance_interval: float = 2.0, drain: bool = True
            ) -> MetricsCollector:
        self._shutdown = False
        for req in requests:
            self.loop.schedule_at(req.arrival, lambda r=req: self.submit(r))
        if self.mode == "decentralized":
            self.loop.schedule(self.gossip_interval, self._gossip_tick)
            if self.restake_interval:
                self.loop.schedule(self.restake_interval, self._restake_tick)
            if rebalance_interval:
                self.loop.schedule(rebalance_interval,
                                   lambda: self._rebalance_tick(rebalance_interval))
        if trace_interval:
            self.loop.schedule(0.0, lambda: self._trace_tick(trace_interval))
        self.loop.run(until=until)
        self._shutdown = True          # periodic tasks stop rescheduling
        if drain:
            self.loop.run()            # let in-flight requests complete
        return self.metrics
