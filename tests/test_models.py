"""Per-architecture smoke tests (reduced configs) + cross-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import registry
from repro.models.config import ModelConfig


def _batch(cfg: ModelConfig, key, b=2, s=64):
    batch = {}
    if cfg.family == "audio":
        batch["encoder_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))
        batch["tokens"] = jax.random.randint(key, (b, 16), 0, cfg.vocab_size)
    elif cfg.embeds_input:
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
class TestSmoke:
    """Reduced variant of each assigned architecture: one forward + one
    decode step on CPU; output shapes + no NaNs."""

    def test_forward_shapes_and_finite(self, arch, key):
        cfg = get_config(arch).smoke().replace(dtype="float32")
        assert cfg.d_model <= 512 and (not cfg.is_moe or cfg.n_experts <= 4)
        params = registry.init(key, cfg)
        batch = _batch(cfg, key)
        logits = registry.apply_logits(params, cfg, batch,
                                       q_chunk=32, kv_chunk=32)
        b = batch.get("tokens", batch.get("embeds")).shape[0]
        s = (batch["tokens"].shape[1] if "tokens" in batch
             else batch["embeds"].shape[1])
        assert logits.shape == (b, s, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any())

    def test_train_step_finite(self, arch, key):
        from repro.training import AdamWConfig, init_state, make_train_step
        cfg = get_config(arch).smoke().replace(dtype="float32")
        state = init_state(key, cfg)
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3),
                                       q_chunk=32, kv_chunk=32))
        batch = _batch(cfg, key, b=2, s=32)
        s_len = (batch["tokens"].shape[1] if "tokens" in batch
                 else batch["embeds"].shape[1])
        batch["labels"] = jax.random.randint(key, (2, s_len), 0,
                                             cfg.vocab_size)
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
        assert float(m["grad_norm"]) > 0

    def test_prefill_decode_matches_apply(self, arch, key):
        cfg = get_config(arch).smoke().replace(dtype="float32")
        if cfg.is_moe:
            # capacity drops are position-dependent in token-choice MoE;
            # disable dropping so the two paths are comparable
            cfg = cfg.replace(capacity_factor=8.0)
        fam = registry.get_family(cfg)
        params = registry.init(key, cfg)
        batch = _batch(cfg, key)
        lg, cache = fam.prefill(params, cfg, batch, q_chunk=32, kv_chunk=32,
                                capacity=96)
        nt = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, cache = fam.decode_step(params, cfg, cache, nt)
        full = dict(batch)
        if cfg.embeds_input:
            # vlm: decode continues in token space; consistency is covered by
            # the dense-family test below, just check finiteness here
            assert not bool(jnp.isnan(lg2).any())
            return
        full["tokens"] = jnp.concatenate([batch["tokens"], nt], axis=1)
        ref = registry.apply_logits(params, cfg, full, q_chunk=32,
                                    kv_chunk=32)
        np.testing.assert_allclose(np.asarray(lg2), np.asarray(ref[:, -1:]),
                                   atol=2e-4, rtol=2e-3)


class TestFamilies:
    def test_sliding_window_variant_long_decode(self, key):
        cfg = get_config("qwen3-8b", "long_500k")
        assert cfg.sliding_window is not None
        sm = cfg.smoke().replace(dtype="float32")
        assert sm.sliding_window == 64
        fam = registry.get_family(sm)
        params = registry.init(key, sm)
        toks = jax.random.randint(key, (1, 200), 0, sm.vocab_size)
        lg, cache = fam.prefill(params, sm, {"tokens": toks},
                                q_chunk=32, kv_chunk=32)
        assert cache["k"].shape[2] == sm.sliding_window   # ring cache
        for _ in range(3):
            nt = jnp.argmax(lg, -1).astype(jnp.int32)
            lg, cache = fam.decode_step(params, sm, cache, nt)
        assert not bool(jnp.isnan(lg).any())

    def test_ssm_decode_state_is_constant_size(self, key):
        cfg = get_config("xlstm-1.3b").smoke().replace(dtype="float32")
        fam = registry.get_family(cfg)
        params = registry.init(key, cfg)
        toks = jax.random.randint(key, (1, 32), 0, cfg.vocab_size)
        _, c1 = fam.prefill(params, cfg, {"tokens": toks})
        toks2 = jax.random.randint(key, (1, 128), 0, cfg.vocab_size)
        _, c2 = fam.prefill(params, cfg, {"tokens": toks2})
        sz = lambda c: sum(x.size for x in jax.tree.leaves(c))
        assert sz(c1) == sz(c2)          # O(1) in sequence length

    def test_moe_load_balance_loss_positive(self, key):
        cfg = get_config("granite-moe-1b-a400m").smoke().replace(
            dtype="float32")
        params = registry.init(key, cfg)
        toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
        _, aux = registry.apply_with_aux(params, cfg, {"tokens": toks},
                                         q_chunk=32, kv_chunk=32)
        assert float(aux) >= 1.0 - 1e-3   # E * Σ f·P >= 1 by Cauchy-Schwarz

    def test_full_configs_match_assignment(self):
        spec = {
            "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
            "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
            "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
            "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
            "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
            "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
            "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
            "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
            "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
            "whisper-base": (6, 512, 8, 8, 2048, 51865),
        }
        for arch, (L, d, h, kv, f, v) in spec.items():
            cfg = get_config(arch)
            got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.d_ff, cfg.vocab_size)
            assert got == (L, d, h, kv, f, v), arch

    def test_moe_extras(self):
        g = get_config("granite-moe-1b-a400m")
        assert (g.n_experts, g.top_k) == (32, 8)
        d = get_config("dbrx-132b")
        assert (d.n_experts, d.top_k) == (16, 4)
