"""Dense decoder-only transformer family.

Covers: starcoder2 (LayerNorm+GeLU+bias), qwen3 (RMSNorm+SwiGLU+qk_norm),
command-r-plus (parallel attention/FFN block, no bias), qwen2-vl (M-RoPE,
embedding inputs), and the sliding-window long-context variants.

Parameters are stacked over layers (leading L axis) so the layer stack is a
single ``lax.scan`` — essential for 64-layer configs to compile quickly in the
multi-pod dry-run.  Activation checkpointing wraps the per-layer block.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import runtime
from repro.models.attention import (decode_attention, flash_attention,
                                    verify_attention)
from repro.models.config import ModelConfig


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------- init
def init(key: jax.Array, cfg: ModelConfig) -> Dict:
    dt = _dt(cfg)
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    keys = jax.random.split(key, 16)

    def stack(initfn, k, *shape_args, **kw):
        ks = jax.random.split(k, L)
        return jnp.stack([initfn(ks[i], *shape_args, **kw) for i in range(L)])

    p: Dict = {
        "embed": cm.embed_init(keys[0], cfg.padded_vocab, d, dt),
        "final_norm": cm.norm_params(d, cfg.norm_type, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.dense_init(keys[1], d, cfg.padded_vocab, dt)

    lyr: Dict = {
        "ln1": _stack_norm(L, d, cfg.norm_type, dt),
        "wq": stack(cm.dense_init, keys[2], d, cfg.q_dim, dt),
        "wk": stack(cm.dense_init, keys[3], d, cfg.kv_dim, dt),
        "wv": stack(cm.dense_init, keys[4], d, cfg.kv_dim, dt),
        "wo": stack(cm.dense_init, keys[5], cfg.q_dim, d, dt),
    }
    if not cfg.parallel_block:
        lyr["ln2"] = _stack_norm(L, d, cfg.norm_type, dt)
    if cfg.qk_norm:
        lyr["q_norm"] = jnp.ones((L, cfg.head_dim), dt)
        lyr["k_norm"] = jnp.ones((L, cfg.head_dim), dt)
    if cfg.use_bias:
        lyr["bq"] = jnp.zeros((L, cfg.q_dim), dt)
        lyr["bk"] = jnp.zeros((L, cfg.kv_dim), dt)
        lyr["bv"] = jnp.zeros((L, cfg.kv_dim), dt)
        lyr["bo"] = jnp.zeros((L, d), dt)
    if cfg.act == "swiglu":
        lyr["w_gate"] = stack(cm.dense_init, keys[6], d, f, dt)
        lyr["w_up"] = stack(cm.dense_init, keys[7], d, f, dt)
        lyr["w_down"] = stack(cm.dense_init, keys[8], f, d, dt)
    else:
        lyr["w_up"] = stack(cm.dense_init, keys[6], d, f, dt)
        lyr["w_down"] = stack(cm.dense_init, keys[7], f, d, dt)
        if cfg.use_bias:
            lyr["b_up"] = jnp.zeros((L, f), dt)
            lyr["b_down"] = jnp.zeros((L, d), dt)
    p["layers"] = lyr
    return p


def _stack_norm(L: int, d: int, norm_type: str, dt) -> Dict:
    if norm_type == "layernorm":
        return {"scale": jnp.ones((L, d), dt), "bias": jnp.zeros((L, d), dt)}
    return {"scale": jnp.ones((L, d), dt)}


# ---------------------------------------------------------------- sub-blocks
def _project_qkv(lp: Dict, cfg: ModelConfig, h: jax.Array, positions: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """h: (B,S,d) -> roped q (B,S,H,dh), k/v (B,S,Hkv,dh)."""
    b, s, _ = h.shape
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.use_bias:
        q = q + lp["bq"][None, None, :]
        k = k + lp["bk"][None, None, :]
        v = v + lp["bv"][None, None, :]
    if not runtime.attn_batch_only():
        q = cm.shard(q, "batch", None, "model")
        k = cm.shard(k, "batch", None, "model")
        v = cm.shard(v, "batch", None, "model")
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = cm.rms_norm(q, lp["q_norm"])
        k = cm.rms_norm(k, lp["k_norm"])
    if cfg.mrope:
        q = cm.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = cm.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp(lp: Dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        g = cm.shard(h @ lp["w_gate"], "batch", None, "model")
        u = cm.shard(h @ lp["w_up"], "batch", None, "model")
        return (jax.nn.silu(g) * u) @ lp["w_down"]
    u = h @ lp["w_up"]
    if cfg.use_bias:
        u = u + lp["b_up"][None, None, :]
    u = cm.shard(u, "batch", None, "model")
    out = cm.gelu(u) @ lp["w_down"]
    if cfg.use_bias:
        out = out + lp["b_down"][None, None, :]
    return out


def _block_train(lp: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                 q_chunk: int, kv_chunk: int, skip_masked: bool) -> jax.Array:
    h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
    q, k, v = _project_qkv(lp, cfg, h, positions)
    attn = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                           q_chunk=q_chunk, kv_chunk=kv_chunk,
                           skip_masked_blocks=skip_masked)
    attn = attn.reshape(x.shape[0], x.shape[1], cfg.q_dim) @ lp["wo"]
    if cfg.use_bias:
        attn = attn + lp["bo"][None, None, :]
    if cfg.parallel_block:
        return cm.shard(x + attn + _mlp(lp, cfg, h), "batch", "seq", None)
    x = x + attn
    h2 = cm.apply_norm(x, lp["ln2"], cfg.norm_type)
    x = x + _mlp(lp, cfg, h2)
    return cm.shard(x, "batch", "seq", None)


# ------------------------------------------------------------------- forward
def apply(params: Dict, cfg: ModelConfig, batch: Dict, *,
          q_chunk: int = 1024, kv_chunk: int = 1024,
          skip_masked_blocks: bool = False) -> jax.Array:
    """Full-sequence forward -> logits (B, S, padded_vocab)."""
    x, positions = embed_inputs(params, cfg, batch)
    block_fn = functools.partial(_block_train, cfg=cfg, positions=positions,
                                 q_chunk=min(q_chunk, x.shape[1]),
                                 kv_chunk=min(kv_chunk, x.shape[1]),
                                 skip_masked=skip_masked_blocks)
    scan_body = jax.checkpoint(lambda carry, lp: (block_fn(lp, x=carry), None))
    x, _ = jax.lax.scan(scan_body, x, params["layers"],
                        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    return logits_of(params, cfg, x)


def embed_inputs(params: Dict, cfg: ModelConfig, batch: Dict
                 ) -> Tuple[jax.Array, jax.Array]:
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(_dt(cfg))
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    x = cm.shard(x, "batch", "seq", None)
    positions = batch.get("positions")
    if positions is None:
        shape = (b, s, 3) if cfg.mrope else (b, s)
        base = jnp.arange(s, dtype=jnp.int32)
        positions = jnp.broadcast_to(base[None, :, None] if cfg.mrope
                                     else base[None, :], shape)
    return x, positions


def logits_of(params: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return cm.shard(x @ head, "batch", None, "model")


# --------------------------------------------------------------- decode path
def _block_decode(lp: Dict, cfg: ModelConfig, x: jax.Array, kv: Dict,
                  length: jax.Array, position: jax.Array
                  ) -> Tuple[jax.Array, Dict]:
    """One layer, one token.  x: (B,1,d); kv holds this layer's cache slices
    (B,C,Hkv,dh) (+ per-token scales when cfg.kv_quant).

    ``length``/``position`` are () for lock-step decode or (B,) for
    slot-based continuous batching, where each row sits at its own depth
    (the serving engine admits new requests into freed slots mid-decode).
    """
    from repro.models.attention import kv_dequantize, kv_quantize
    b = x.shape[0]
    cap = kv["k"].shape[1]
    h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
    pos = jnp.broadcast_to(jnp.reshape(position, (-1, 1)), (b, 1))
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.reshape(position, (-1, 1, 1)), (b, 1, 3))
    q, k, v = _project_qkv(lp, cfg, h, pos)
    slot = jnp.mod(length, cap)                      # ring write (window cache)
    n_valid = jnp.minimum(length + 1, cap)
    writes = {"k": k, "v": v}
    if cfg.kv_quant:
        writes["k"], writes["k_scale"] = kv_quantize(k)
        writes["v"], writes["v_scale"] = kv_quantize(v)
    if jnp.ndim(length) > 0:
        # per-row depths: scatter each row's token at its own slot, attend
        # its own valid prefix (decode_attention takes (B,) cache lengths)
        rows = jnp.arange(b)
        kv = {name: kv[name].at[rows, slot].set(w[:, 0])
              for name, w in writes.items()}
        if cfg.kv_quant:
            kf = kv_dequantize(kv["k"], kv["k_scale"], _dt(cfg))
            vf = kv_dequantize(kv["v"], kv["v_scale"], _dt(cfg))
        else:
            kf, vf = kv["k"], kv["v"]
        attn = decode_attention(q, kf, vf, n_valid)
    elif runtime.decode_seq_shard():
        # §Perf: shard-local ring write + LSE-combined partial attention —
        # avoids GSPMD's cache-sized collectives for the seq-sharded update
        from repro.models.attention import decode_attention_seqsharded
        if cfg.kv_quant:
            attn, kc, vc, ks_, vs_ = decode_attention_seqsharded(
                q, kv["k"], kv["v"], writes["k"], writes["v"], slot, n_valid,
                scales=(kv["k_scale"], kv["v_scale"],
                        writes["k_scale"], writes["v_scale"]))
            kv = {"k": kc, "v": vc, "k_scale": ks_, "v_scale": vs_}
        else:
            attn, kc, vc = decode_attention_seqsharded(
                q, kv["k"], kv["v"], k, v, slot, n_valid)
            kv = {"k": kc, "v": vc}
    else:
        kv = {name: jax.lax.dynamic_update_slice(
            kv[name], w, (0, slot, 0, 0)) for name, w in writes.items()}
        if cfg.kv_quant:
            # int8 cache stream; dequant fuses into the attention read on TPU
            kf = kv_dequantize(kv["k"], kv["k_scale"], _dt(cfg))
            vf = kv_dequantize(kv["v"], kv["v_scale"], _dt(cfg))
        else:
            kf, vf = kv["k"], kv["v"]
        attn = decode_attention(q, kf, vf, n_valid)
    attn = attn.reshape(b, 1, cfg.q_dim) @ lp["wo"]
    if cfg.use_bias:
        attn = attn + lp["bo"][None, None, :]
    if cfg.parallel_block:
        return x + attn + _mlp(lp, cfg, h), kv
    x = x + attn
    h2 = cm.apply_norm(x, lp["ln2"], cfg.norm_type)
    return x + _mlp(lp, cfg, h2), kv


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict, token: jax.Array
                ) -> Tuple[jax.Array, Dict]:
    """cache: {"k": (L,B,C,Hkv,dh), "v": ..., "length": () or (B,)} ;
    token: (B,1).  A (B,) length decodes each row at its own depth (slot
    continuous batching).  With cfg.kv_quant the caches are int8 plus
    "k_scale"/"v_scale"."""
    x = jnp.take(params["embed"], token, axis=0)
    length = cache["length"]
    kv_names = [n for n in ("k", "v", "k_scale", "v_scale") if n in cache]

    def step(x, xs):
        lp, kv = xs
        x, kv = _block_decode(lp, cfg, x, kv, length, length)
        return x, kv

    x, kv_new = jax.lax.scan(
        step, x, (params["layers"], {n: cache[n] for n in kv_names}),
        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = logits_of(params, cfg, x)
    return logits, {**kv_new, "length": length + 1}


# ---------------------------------------------------------------- paged decode
PAGED_POOL_NAMES = ("k_pool", "v_pool", "k_scale_pool", "v_scale_pool")


def init_paged_pools(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=None) -> Dict:
    """Allocate the shared KV page pools: {"k_pool","v_pool"} each
    (L, P, page, Hkv, dh).  Page 0 is conventionally the engine's scratch
    page (writes for unallocated rows land there and are never attended).

    With ``cfg.kv_quant`` the pools are int8 and two parallel *scale pools*
    {"k_scale_pool","v_scale_pool"} (L, P, page, Hkv, 1) bf16 ride the same
    block-table indirection — one per-(token, head) scale per pool entry
    (DESIGN.md §6.1-paged).
    """
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        sshape = shape[:-1] + (1,)
        return {"k_pool": jnp.zeros(shape, jnp.int8),
                "v_pool": jnp.zeros(shape, jnp.int8),
                "k_scale_pool": jnp.zeros(sshape, jnp.bfloat16),
                "v_scale_pool": jnp.zeros(sshape, jnp.bfloat16)}
    dt = dtype or _dt(cfg)
    return {"k_pool": jnp.zeros(shape, dt), "v_pool": jnp.zeros(shape, dt)}


def prefill_to_pages(pools: Dict, kv: Dict, phys_pages: jax.Array) -> Dict:
    """Scatter a contiguous prefill cache into pool pages.

    pools: {"k_pool","v_pool"[,"k_scale_pool","v_scale_pool"]}
    (L, P, page, Hkv, dh|1); kv: {"k","v"[,"k_scale","v_scale"]}
    (L, n, plen, Hkv, dh|1) with plen a multiple of the page size — a
    quantized prefill cache is scattered as-is, NOT re-quantized, so paged
    pages hold bit-identical values to the slot cache;
    phys_pages: (n, plen//page) int32 physical page per (row, logical page).
    Entries for pages past a row's real prompt point at the scratch page 0
    (several rows may alias it; the garbage is masked by per-row lengths).
    """
    page = pools["k_pool"].shape[2]
    out = {}
    for pname in PAGED_POOL_NAMES:
        if pname not in pools:
            continue
        name = pname[:-5]                              # strip "_pool"
        L, n, plen = kv[name].shape[:3]
        src = kv[name].reshape((L, n, plen // page, page) + kv[name].shape[3:])
        out[pname] = pools[pname].at[:, phys_pages].set(src)
    return out


def _gather_layer_pages(pools: Dict, l: jax.Array, block_tables: jax.Array,
                        cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Gather layer ``l``'s pages into contiguous (B, maxp*page, Hkv, dh)
    K/V, dequantizing int8 pools through their scale pools (the same
    ``kv_dequantize`` the slot path uses, so quantized-paged stays
    bit-identical to quantized-slot)."""
    from repro.models.attention import kv_dequantize
    b, maxp = block_tables.shape
    page = pools["k_pool"].shape[2]

    def gather(pname):
        p = pools[pname][l][block_tables]
        return p.reshape((b, maxp * page) + p.shape[3:])

    kg, vg = gather("k_pool"), gather("v_pool")
    if "k_scale_pool" in pools:
        kg = kv_dequantize(kg, gather("k_scale_pool"), _dt(cfg))
        vg = kv_dequantize(vg, gather("v_scale_pool"), _dt(cfg))
    return kg, vg


def _scatter_pool_writes(pools: Dict, l: jax.Array, phys_page: jax.Array,
                         page_slot: jax.Array, k: jax.Array, v: jax.Array,
                         squeeze: bool) -> Dict:
    """Write new-token KV into layer ``l``'s pages, quantizing on page
    write for int8 pools.  k/v: (B, K, Hkv, dh); phys_page/page_slot: (B,)
    when ``squeeze`` (single token) else (B, K)."""
    from repro.models.attention import kv_quantize
    writes = {"k_pool": k, "v_pool": v}
    if "k_scale_pool" in pools:
        writes["k_pool"], writes["k_scale_pool"] = kv_quantize(k)
        writes["v_pool"], writes["v_scale_pool"] = kv_quantize(v)
    return {name: pools[name].at[l, phys_page, page_slot].set(
                w[:, 0] if squeeze else w)
            for name, w in writes.items()}


def _block_decode_paged(lp: Dict, cfg: ModelConfig, x: jax.Array, pools: Dict,
                        l: jax.Array, block_tables: jax.Array,
                        lengths: jax.Array, phys_page: jax.Array,
                        page_slot: jax.Array) -> Tuple[jax.Array, Dict]:
    """One layer, one token, against layer ``l`` of the KV page pools.

    x: (B,1,d); pools: full (L, P, page, Hkv, dh|1) arrays carried through
    the layer scan — indexing layer ``l`` here (instead of slicing pools as
    scan xs) keeps the update in-place under buffer donation, so decode
    cost does not scale with pool size (§Perf-kernels); block_tables:
    (B, maxp); lengths: (B,) valid tokens per row; phys_page/page_slot:
    (B,) physical page and in-page slot where this token's KV is written
    (rows without an allocated page are pointed at the scratch page 0 by
    the engine — their write is garbage that a later real write or mask
    supersedes).
    """
    b = x.shape[0]
    h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
    pos = jnp.broadcast_to(jnp.reshape(lengths, (-1, 1)), (b, 1))
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.reshape(lengths, (-1, 1, 1)), (b, 1, 3))
    q, k, v = _project_qkv(lp, cfg, h, pos)
    pools = _scatter_pool_writes(pools, l, phys_page, page_slot, k, v,
                                 squeeze=True)
    kg, vg = _gather_layer_pages(pools, l, block_tables, cfg)
    attn = decode_attention(q, kg, vg, lengths + 1)
    attn = attn.reshape(b, 1, cfg.q_dim) @ lp["wo"]
    if cfg.use_bias:
        attn = attn + lp["bo"][None, None, :]
    if cfg.parallel_block:
        return x + attn + _mlp(lp, cfg, h), pools
    x = x + attn
    h2 = cm.apply_norm(x, lp["ln2"], cfg.norm_type)
    return x + _mlp(lp, cfg, h2), pools


def paged_decode_step(params: Dict, cfg: ModelConfig, cache: Dict,
                      token: jax.Array) -> Tuple[jax.Array, Dict]:
    """One decode step against paged KV (DESIGN.md §6.1, paged backend).

    cache: {"k_pool"/"v_pool": (L, P, page, Hkv, dh)
            [, "k_scale_pool"/"v_scale_pool": (L, P, page, Hkv, 1)],
            "block_tables": (B, maxp) int32, "lengths": (B,) int32};
    token: (B,1).  Every row decodes at its own depth; the new token's KV is
    scattered into physical page ``bt[b, lengths[b] // page]`` at slot
    ``lengths[b] % page`` (quantize-on-write for int8 pools).  The engine
    guarantees that page is allocated for rows that are actually decoding;
    riding-along rows resolve to the scratch page 0.

    The pools ride the layer scan as **carry** (layer picked by index), not
    as sliced xs — under ``jax.jit(..., donate_argnums=...)`` the scatter
    is then a true in-place update and step cost is independent of pool
    size (§Perf-kernels).  Returns (logits, cache with lengths+1).
    """
    x = jnp.take(params["embed"], token, axis=0)
    bt = cache["block_tables"]
    lengths = cache["lengths"]
    page = cache["k_pool"].shape[2]
    maxp = bt.shape[1]
    rows = jnp.arange(bt.shape[0])
    page_idx = jnp.minimum(lengths // page, maxp - 1)
    phys_page = bt[rows, page_idx]
    page_slot = lengths % page
    pool_names = [n for n in PAGED_POOL_NAMES if n in cache]

    def step(carry, xs):
        x, pools = carry
        lp, l = xs
        x, pools = _block_decode_paged(lp, cfg, x, pools, l, bt, lengths,
                                       phys_page, page_slot)
        return (x, pools), None

    (x, pools_new), _ = jax.lax.scan(
        step, (x, {n: cache[n] for n in pool_names}),
        (params["layers"], jnp.arange(cfg.n_layers)),
        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = logits_of(params, cfg, x)
    return logits, {**pools_new, "block_tables": bt, "lengths": lengths + 1}


def _block_verify_paged(lp: Dict, cfg: ModelConfig, x: jax.Array, pools: Dict,
                        l: jax.Array, block_tables: jax.Array,
                        lengths: jax.Array, phys_page: jax.Array,
                        page_slot: jax.Array) -> Tuple[jax.Array, Dict]:
    """One layer, K new tokens, against layer ``l`` of the KV page pools
    (speculative verify, DESIGN.md §6.1-spec).

    x: (B,K,d); pools: full (L, P, page, Hkv, dh|1) arrays carried through
    the layer scan (same in-place-under-donation layout as
    ``_block_decode_paged``); block_tables: (B, maxp); lengths: (B,) valid
    tokens per row BEFORE the K new tokens; phys_page/page_slot: (B,K)
    physical page and in-page slot where token j's KV is written (position
    ``lengths[b]+j``; rows without an allocated page there are pointed at
    the scratch page 0 by the engine).
    """
    b, kq = x.shape[:2]
    h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
    pos = lengths[:, None] + jnp.arange(kq, dtype=lengths.dtype)[None, :]
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[..., None], (b, kq, 3))
    q, k, v = _project_qkv(lp, cfg, h, pos)
    pools = _scatter_pool_writes(pools, l, phys_page, page_slot, k, v,
                                 squeeze=False)
    kg, vg = _gather_layer_pages(pools, l, block_tables, cfg)
    attn = verify_attention(q, kg, vg, lengths)
    attn = attn.reshape(b, kq, cfg.q_dim) @ lp["wo"]
    if cfg.use_bias:
        attn = attn + lp["bo"][None, None, :]
    if cfg.parallel_block:
        return x + attn + _mlp(lp, cfg, h), pools
    x = x + attn
    h2 = cm.apply_norm(x, lp["ln2"], cfg.norm_type)
    return x + _mlp(lp, cfg, h2), pools


def paged_verify_step(params: Dict, cfg: ModelConfig, cache: Dict,
                      tokens: jax.Array) -> Tuple[jax.Array, Dict]:
    """One speculative verify step against paged KV (DESIGN.md §6.1-spec).

    cache: {"k_pool"/"v_pool": (L, P, page, Hkv, dh)
            [, "k_scale_pool"/"v_scale_pool": (L, P, page, Hkv, 1)],
            "block_tables": (B, maxp) int32, "lengths": (B,) int32};
    tokens: (B, K) — the pending token followed by the k draft tokens.
    Token j's KV is scattered into physical page
    ``bt[b, (lengths[b]+j) // page]`` at slot ``(lengths[b]+j) % page``
    (quantize-on-write for int8 pools), then all K positions attend the
    gathered pages with per-query causal bounds (query j sees positions
    ``<= lengths[b]+j``).  The engine guarantees pages are allocated
    through ``lengths+K`` for verifying rows; riding-along rows resolve to
    the scratch page 0.  Pools are scan carry, in-place under donation
    (§Perf-kernels).  Returns (logits (B,K,V), cache) — ``lengths`` is NOT
    advanced: the engine owns advancement, which depends on how many draft
    tokens were accepted.
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    bt = cache["block_tables"]
    lengths = cache["lengths"]
    page = cache["k_pool"].shape[2]
    maxp = bt.shape[1]
    b, kq = tokens.shape
    rows = jnp.arange(b)
    pos_abs = lengths[:, None] + jnp.arange(kq, dtype=lengths.dtype)[None, :]
    page_idx = jnp.minimum(pos_abs // page, maxp - 1)
    phys_page = bt[rows[:, None], page_idx]
    page_slot = pos_abs % page
    pool_names = [n for n in PAGED_POOL_NAMES if n in cache]

    def step(carry, xs):
        x, pools = carry
        lp, l = xs
        x, pools = _block_verify_paged(lp, cfg, x, pools, l, bt, lengths,
                                       phys_page, page_slot)
        return (x, pools), None

    (x, pools_new), _ = jax.lax.scan(
        step, (x, {n: cache[n] for n in pool_names}),
        (params["layers"], jnp.arange(cfg.n_layers)),
        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = logits_of(params, cfg, x)
    return logits, {**pools_new, "block_tables": bt, "lengths": lengths}


def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> Dict:
    dt = dtype or _dt(cfg)
    cap = capacity if cfg.sliding_window is None else min(capacity,
                                                          cfg.sliding_window)
    shape = (cfg.n_layers, batch, cap, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "length": jnp.zeros((), jnp.int32)}


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            q_chunk: int = 1024, kv_chunk: int = 1024,
            capacity: Optional[int] = None,
            last_positions: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Dict]:
    """Run the prompt, build the KV cache, return last-position logits.

    ``capacity`` is the cache size to allocate (>= prompt length for full
    attention; defaults to the prompt length, which leaves no room to decode —
    the serving engine passes prompt+max_new).  Sliding-window configs use a
    ring cache of size ``sliding_window`` with the invariant
    ``slot(position p) = p % window``.

    ``last_positions`` ((B,) int32) extracts each row's logits at its own
    final *real* token instead of the batch's last column — the slot engine
    right-pads mixed-length prompts, which causal masking keeps inert, so a
    row's true continuation point is ``len(prompt_i) - 1``.
    """
    x, positions = embed_inputs(params, cfg, batch)
    b, s = x.shape[:2]
    if cfg.sliding_window is None:
        cap = max(s, capacity or s)
    else:
        cap = min(cfg.sliding_window, capacity or cfg.sliding_window)

    def step(carry, lp):
        x = carry
        h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
        q, k, v = _project_qkv(lp, cfg, h, positions)
        attn = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                               q_chunk=min(q_chunk, s), kv_chunk=min(kv_chunk, s))
        attn = attn.reshape(b, s, cfg.q_dim) @ lp["wo"]
        if cfg.use_bias:
            attn = attn + lp["bo"][None, None, :]
        if cfg.parallel_block:
            x = x + attn + _mlp(lp, cfg, h)
        else:
            x = x + attn
            x = x + _mlp(lp, cfg, cm.apply_norm(x, lp["ln2"], cfg.norm_type))
        x = cm.shard(x, "batch", "seq", None)

        def ring(a):
            if cap <= s:
                # keep the last ``cap`` tokens, ring-rotated so that the
                # token at absolute position p sits at slot p % cap.
                return jnp.roll(a[:, -cap:], shift=s % cap, axis=1)
            padw = [(0, 0), (0, cap - s)] + [(0, 0)] * (a.ndim - 2)
            return jnp.pad(a, padw)

        out = {"k": k, "v": v}
        if cfg.kv_quant:
            from repro.models.attention import kv_quantize
            out["k"], out["k_scale"] = kv_quantize(k)
            out["v"], out["v_scale"] = kv_quantize(v)
        return x, {n: ring(a) for n, a in out.items()}

    step = jax.checkpoint(step)
    x, kvs = jax.lax.scan(step, x, params["layers"],
                          unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    if last_positions is None:
        x_last = x[:, -1:]
    else:
        x_last = x[jnp.arange(b), last_positions][:, None]
    logits = logits_of(params, cfg, x_last)
    cache = {**kvs, "length": jnp.asarray(s, jnp.int32)}
    return logits, cache
