"""Minimal, deterministic stand-in for the ``hypothesis`` API.

The offline test container cannot ``pip install hypothesis``; six test
modules use a small slice of its API (``@given``, ``@settings`` and the
``integers / floats / booleans / lists / sampled_from / composite``
strategies).  This module implements exactly that slice with seeded
pseudo-random example generation, so the property tests still run many
distinct examples — reproducibly, since the seed is derived from the test's
qualified name rather than wall clock.

The root ``conftest.py`` installs this module into ``sys.modules`` as
``hypothesis`` ONLY when the real package is absent; installing hypothesis
in the environment transparently switches the suite back to the real
engine (shrinking, the full strategy library, and all).

Intentional differences from real hypothesis:

* no shrinking — a failing example is re-raised with the drawn values
  attached to the assertion message instead;
* no coverage-guided generation — plain uniform draws;
* ``deadline`` / unknown ``settings`` kwargs are accepted and ignored.
"""

from __future__ import annotations

import functools
import random
import zlib
from typing import Any, Callable, List, Optional, Sequence, Tuple

__version__ = "0.0-repro-shim"

DEFAULT_MAX_EXAMPLES = 20


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class SearchStrategy:
    """A strategy is just a named wrapper around ``draw(rng) -> value``."""

    def __init__(self, draw_fn: Callable[[random.Random], Any], label: str):
        self._draw = draw_fn
        self._label = label

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover — debug aid
        return self._label


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          f"integers({min_value}, {max_value})")


def floats(min_value: float, max_value: float, *,
           allow_nan: bool = False, allow_infinity: bool = False
           ) -> SearchStrategy:
    # boundary values are disproportionately bug-prone; visit them sometimes
    def draw(rng: random.Random) -> float:
        r = rng.random()
        if r < 0.05:
            return float(min_value)
        if r < 0.10:
            return float(max_value)
        return rng.uniform(min_value, max_value)

    return SearchStrategy(draw, f"floats({min_value}, {max_value})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))],
                          f"sampled_from({elements!r})")


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: Optional[int] = None) -> SearchStrategy:
    hi = min_size + 10 if max_size is None else max_size

    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, hi)
        return [elements.draw(rng) for _ in range(n)]

    return SearchStrategy(draw, f"lists({elements!r})")


def composite(fn: Callable) -> Callable[..., SearchStrategy]:
    """``@composite`` — ``fn(draw, *args)`` builds one example."""

    @functools.wraps(fn)
    def builder(*args: Any, **kwargs: Any) -> SearchStrategy:
        def draw_example(rng: random.Random) -> Any:
            return fn(lambda strat: strat.draw(rng), *args, **kwargs)

        return SearchStrategy(draw_example, f"composite({fn.__name__})")

    return builder


class _StrategiesModule:
    """Attribute bag standing in for the ``hypothesis.strategies`` module."""

    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    composite = staticmethod(composite)
    SearchStrategy = SearchStrategy


strategies = _StrategiesModule()


# ---------------------------------------------------------------------------
# settings / given
# ---------------------------------------------------------------------------

class settings:  # noqa: N801 — mirrors the hypothesis name
    """Decorator recording per-test run options (``max_examples`` only)."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline: Any = None, **_ignored: Any):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn: Callable) -> Callable:
        fn._shim_settings = self  # read by @given, whichever wraps whichever
        return fn


def _seed_for(fn: Callable) -> int:
    name = getattr(fn, "__qualname__", getattr(fn, "__name__", "test"))
    return zlib.crc32(name.encode("utf-8"))


def given(*strat_args: SearchStrategy, **strat_kwargs: SearchStrategy):
    """Run the test once per drawn example (deterministic per-test seed)."""

    def decorate(fn: Callable) -> Callable:

        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> None:
            opts: settings = (getattr(wrapper, "_shim_settings", None)
                              or getattr(fn, "_shim_settings", None)
                              or settings())
            rng = random.Random(_seed_for(fn))
            for i in range(opts.max_examples):
                ex_args = tuple(s.draw(rng) for s in strat_args)
                ex_kwargs = {k: s.draw(rng) for k, s in strat_kwargs.items()}
                try:
                    fn(*args, *ex_args, **kwargs, **ex_kwargs)
                except _UnsatisfiedAssumption:
                    continue
                except Exception as e:  # noqa: BLE001 — annotate + re-raise
                    detail = (f"[hypothesis-shim] falsifying example "
                              f"#{i + 1}: args={ex_args!r} "
                              f"kwargs={ex_kwargs!r}")
                    try:
                        annotated = type(e)(f"{e}\n{detail}")
                    except TypeError:  # exotic exception signature
                        raise e
                    raise annotated.with_traceback(
                        e.__traceback__) from None

        # pytest introspects signatures through __wrapped__ and would treat
        # the strategy-supplied parameters as fixtures; hide them.  (pytest
        # also special-cases a ``hypothesis`` attribute — don't set one.)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return decorate


def assume(condition: bool) -> bool:
    """Real hypothesis aborts the example; the shim just skips via raise."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass
