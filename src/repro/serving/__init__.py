from repro.serving.engine import Engine, EngineStats, GenRequest
from repro.serving.executor import EngineExecutor
from repro.serving.sampling import sample
