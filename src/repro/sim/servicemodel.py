"""Analytic node service model: hardware x model x backend -> tokens/s.

The paper's Fig 4/5/7/8 numbers are dominated by queueing delay, not kernel
micro-performance, so we model a node's backend as a concurrency-limited
server whose per-request service time is::

    T(req) = prompt / prefill_tps + output / decode_tps(batch)

with decode throughput shared beyond a saturation knee (continuous batching:
per-stream decode speed is ~flat until the batch saturates compute/HBM, then
degrades ~linearly).  Calibration constants below are order-of-magnitude
figures from public vLLM/SGLang benchmarks for the paper's hardware.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

# rough per-(GPU) capability scalars (A100 = 1.0 reference)
GPU_SCALE: Dict[str, float] = {
    "A100": 1.00, "4xA100": 3.40, "L40S": 0.62, "ADA6000": 0.60,
    "RTX4090": 0.55, "RTX3090": 0.30,
}
# serving backend efficiency (paper Fig 6c: FlashInfer > Triton >> SDPA)
BACKEND_SCALE: Dict[str, float] = {
    "sglang": 1.00, "vllm": 0.95,
    "flashinfer": 1.00, "triton": 0.98, "sdpa": 0.55,
}
# model-size scalar: tokens/s ~ 1/params (memory-bound decode)
MODEL_PARAMS_B: Dict[str, float] = {
    "qwen3-32b": 32.8, "qwen3-8b": 8.2, "qwen3-4b": 4.0, "qwen3-0.6b": 0.6,
    "llama3.1-8b": 8.0, "deepseek-qwen-7b": 7.6,
}
# quantization: speed multiplier and quality delta (Fig 6b)
QUANT_SPEED: Dict[str, float] = {"bf16": 1.0, "fp8wo": 1.15, "int4wo-128": 1.3, "int4wo-32": 1.25}

# reference: Qwen3-8B bf16 on A100 under SGLang
REF_PREFILL_TPS = 8000.0   # prompt tokens/s
REF_DECODE_TPS = 95.0      # per-stream decode tokens/s at low batch
REF_SATURATION = 24        # streams before decode throughput is shared

# KV-memory admission is a TOKEN budget (prompt + output reserved per stream),
# not a stream count; this converts the legacy max_concurrency stream limit
# into tokens at the paper workload's mean footprint (~512 prompt + ~5k output)
KV_TOKENS_PER_STREAM = 6144

# --- disaggregated prefill/decode transfer cost (DESIGN.md §6.1-disagg) -----
# KV bytes per token for the reference model (Qwen3-8B bf16: K+V tensors x
# 36 layers x 8 KV heads x 128 head_dim x 2 bytes/elem)
KV_BYTES_PER_TOKEN = 2 * 36 * 8 * 128 * 2              # 147456 B/token
# effective inter-node KV link (10 Gb/s datacenter ethernet) plus a fixed
# per-handoff setup cost (connection + block-table metadata)
TRANSFER_BYTES_PER_S = 1.25e9
TRANSFER_BASE_S = 0.002
# EMA step for the router's per-node observed transfer rate: each completed
# handoff window updates rate <- (1 - beta) * rate + beta * observed, seeded
# from TRANSFER_BYTES_PER_S so routing matches the static model until real
# ExecutorLoad.handoff_bytes observations move it (core/network._est_wait).
TRANSFER_EMA_BETA = 0.2

# --- gossip load-dissemination plane (DESIGN.md §6.2-gossip) ----------------
# Digests of ExecutorLoad piggyback on gossip rounds at the same cadence as
# membership heartbeats; routing then ranks candidates from the local stale
# digest table instead of probing every candidate inline.
DIGEST_INTERVAL_S = 1.0
# Staleness discount: a digest of age `a` is trusted with weight
# exp(-a / DIGEST_STALENESS_TAU_S); as trust decays the inferred pressure
# regresses toward the neutral prior below (an unknown peer is assumed
# half-loaded, neither a magnet nor a repellent for offloads).
DIGEST_STALENESS_TAU_S = 5.0
DIGEST_PRESSURE_PRIOR = 0.5
# Pressure gap (after discounting) under which the digest ranking cannot
# separate the top two candidates and routing falls back to live probes.
DIGEST_TIE_EPS = 0.05

# --- cross-request prefix caching (DESIGN.md §6.1-prefix) -------------------
# EMA step for the executor's online cache-hit-rate estimate: per admitted
# request, hit_rate <- (1 - beta) * hit_rate + beta * (cached / prompt).
# Seeds at 0.0 (a fresh pool has nothing cached), so sim and engine agree
# until observations move it — same pattern as SPEC_ALPHA0 below.
PREFIX_HIT_EMA_BETA = 0.2
# Resident-prefix fingerprint width: a load digest advertises up to this many
# distinct prefix identities (most recently touched first) for cache-affinity
# dispatch, and the simulated executor's prefix cache retains this many
# distinct prefixes (LRU beyond it) so the fingerprint IS the sim cache.
PREFIX_FINGERPRINT_K = 8

# --- speculative decoding (DESIGN.md §6.1-spec) -----------------------------
# Default draft depth: k draft tokens verified per target forward.
SPEC_K = 4
# Prior per-token draft acceptance rate.  This single constant seeds BOTH the
# real engine's online EMA (Engine.spec_alpha) and the simulated
# SpecTokenBucketExecutor's configured rate, so sim and engine start from the
# same expected-tokens-per-step and their admission/estimate decisions agree
# until real observations move the EMA (sim-vs-engine agreement test in
# tests/test_spec.py, same pattern as paged_admit_ok).
SPEC_ALPHA0 = 0.7
# EMA step for the engine's online acceptance-rate estimate: per verify step,
# alpha <- (1 - beta) * alpha + beta * (accepted / k).
SPEC_EMA_BETA = 0.1
# Fractional per-verify-step overhead of running the draft model (k draft
# forwards of a ~10x smaller model plus the verify's extra query positions,
# relative to one target decode step).  The sim charges it against decode
# throughput; the real engine measures it (EngineStats.draft_wall_s).
SPEC_OVERHEAD = 0.15


@dataclass(frozen=True)
class BackendProfile:
    """Resolved capability of one node's serving backend.

    Execution itself lives in ``repro.sim.executor`` (TokenBucketExecutor);
    ``service_time`` is the analytic steady-state formula the executor must
    reduce to at constant occupancy, and may only be called from the
    executor module (grep-guarded in ``tests/test_compat.py``).
    """

    prefill_tps: float
    decode_tps: float          # per-stream, unsaturated
    saturation: int            # concurrent streams at the knee
    max_concurrency: int       # legacy stream-count admission limit
    quality: float             # latent response quality q_i in [0, 1]
    kv_token_budget: int = 0   # KV admission budget in tokens (0 = derive)

    def service_time(self, prompt: int, output: int, n_active: int) -> float:
        """Expected generation wall time with ``n_active`` concurrent streams."""
        share = max(1.0, n_active / self.saturation)
        return prompt / self.prefill_tps + output / (self.decode_tps / share)


def make_profile(model: str = "qwen3-8b", gpu: str = "A100", backend: str = "sglang",
                 quant: str = "bf16", quality: float = 0.5) -> BackendProfile:
    g = GPU_SCALE[gpu]
    b = BACKEND_SCALE[backend]
    m = MODEL_PARAMS_B[model]
    q = QUANT_SPEED[quant]
    size_scale = 8.2 / m            # vs reference 8B
    prefill = REF_PREFILL_TPS * g * b * size_scale
    decode = REF_DECODE_TPS * g * b * q * size_scale ** 0.7
    sat = max(2, int(REF_SATURATION * g * size_scale))
    return BackendProfile(
        prefill_tps=prefill, decode_tps=decode, saturation=sat,
        max_concurrency=4 * sat, quality=quality,
        kv_token_budget=4 * sat * KV_TOKENS_PER_STREAM)


# latent quality per model size / quantization, set to reproduce the paper's
# duel win rates (Fig 6a: 0.57/0.53/0.39, Fig 6b: 0.54/0.49/0.47).
MODEL_QUALITY: Dict[str, float] = {
    "qwen3-32b": 0.80, "qwen3-8b": 0.72, "qwen3-4b": 0.64, "qwen3-0.6b": 0.36,
    "llama3.1-8b": 0.66, "deepseek-qwen-7b": 0.62,
}
QUANT_QUALITY_DELTA: Dict[str, float] = {"bf16": 0.0, "fp8wo": -0.04, "int4wo-128": -0.20, "int4wo-32": -0.28}
