"""Shared layers: norms, activations, RoPE / M-RoPE, initializers, sharding."""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import meshenv

# ---------------------------------------------------------------------------
# logical sharding: annotate intermediates; the mesh context resolves axes.
# data-parallel batch spans ("pod", "data"); tensor-parallel spans "model".
# ---------------------------------------------------------------------------

BATCH_AXES = ("pod", "data")


def _mesh_axes() -> Tuple[str, ...]:
    return meshenv.axis_names()


def logical(*axes: Optional[str]) -> P:
    """Build a PartitionSpec, dropping mesh axes that don't exist.

    ``logical("batch", None, "model")`` maps batch -> ("pod","data") when the
    pod axis exists, else ("data",).
    """
    from repro.models import runtime
    present = _mesh_axes()
    spec = []
    for a in axes:
        if a is None:
            spec.append(None)
        elif a == "batch":
            got = tuple(x for x in BATCH_AXES if x in present)
            spec.append(got if got else None)
        elif a == "seq":
            # sequence parallelism (§Perf variant): shard the sequence dim
            # over 'model' only when the flag is on
            spec.append("model" if (runtime.seq_parallel()
                                    and "model" in present) else None)
        else:
            spec.append(a if a in present else None)
    return P(*spec)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the ambient mesh (no-op without mesh)."""
    if not _mesh_axes():
        return x
    return meshenv.with_sharding_constraint(x, logical(*axes))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def _per_channel(v: jax.Array, ndim: int) -> jax.Array:
    """Reshape a (D,) per-channel vector for an explicit rank-``ndim``
    broadcast; the suite runs with rank promotion set to "raise"."""
    return v.reshape((1,) * (ndim - 1) + v.shape)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * _per_channel(scale.astype(jnp.float32), x.ndim)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * _per_channel(scale.astype(jnp.float32), x.ndim)
            + _per_channel(bias.astype(jnp.float32), x.ndim)).astype(dt)


def apply_norm(x: jax.Array, p: dict, norm_type: str) -> jax.Array:
    if norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def norm_params(d: int, norm_type: str, dtype) -> dict:
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                    # (D/2,)
    ang = (positions[..., None].astype(jnp.float32)
           * freqs[None, None, :])                            # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Sequence[int]) -> jax.Array:
    """Qwen2-VL M-RoPE. x: (B,S,H,D); positions: (B,S,3) = (t,h,w) ids.

    The D/2 rotary frequencies are split into ``sections`` (t,h,w); each
    section rotates by its own position id (arXiv:2409.12191 §3.1).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    assert sum(sections) == d // 2, (
        f"mrope_sections {sections} must sum to head_dim/2 = {d // 2}")
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])  # (D/2,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                         # (B,S,3)
        jnp.broadcast_to(sec[None, None, :], positions.shape[:2] + sec.shape),
        axis=-1)                                               # (B,S,D/2)
    ang = pos * freqs[None, None, :]                           # (B,S,D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
