"""Pallas TPU multi-token verify: flash attention for speculative decoding.

Speculative decoding (DESIGN.md §6.1-spec) verifies ``K = spec_k + 1`` new
tokens — the pending token plus k draft tokens — in ONE target forward
against the paged KV pool.  By the time attention runs, the K tokens' KV has
already been scattered into pool pages at positions
``lengths[b] .. lengths[b]+K-1``; what distinguishes this kernel from the
single-token ``paged_decode`` is the *per-query* causal bound: draft query
``j`` (absolute position ``lengths[b] + j``) may attend positions
``<= lengths[b] + j``, so each query row of the block gets its own length
limit instead of the row-wide scalar.

Layout and tuning are shared with ``paged_decode`` (DESIGN.md
§Perf-kernels): head-fused ``(P, Hkv, page, D)`` pool blocks, a
``(B, padded_pages // pages_per_step)`` grid with the block table padded
to a multiple of ``pages_per_step`` using scratch-page entries, and the
same scalar-prefetch index maps.  The K query positions of all ``rep``
grouped heads ride in one ``(hkv, K*rep, d)`` q block.  The quantized
variant dequantizes int8 pages in-body via
``models.attention.kv_dequantize``, same as the decode kernel.

The jnp oracles are ``ref.paged_verify_ref`` / ``ref.paged_verify_quant_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.models.attention import NEG_INF, kv_dequantize
from repro.kernels.paged_decode import _paged_attention


def _verify_kernel(bt_ref, len_ref, q_ref, *refs, page: int, pps: int,
                   quant: bool, scale: float, rep: int):
    """refs: k×pps, v×pps[, k_scale×pps, v_scale×pps], o, acc, m, l."""
    ip = pl.program_id(1)
    np_ = pl.num_programs(1)
    base_len = len_ref[pl.program_id(0)]
    n_in = pps * (4 if quant else 2)
    k_refs, v_refs = refs[:pps], refs[pps:2 * pps]
    ks_refs = refs[2 * pps:3 * pps] if quant else ()
    vs_refs = refs[3 * pps:4 * pps] if quant else ()
    o_ref, acc_ref, m_ref, l_ref = refs[n_in:]

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (hkv, K*rep, d)
    # q-block row r is draft query j = r // rep, at absolute position
    # base_len + j, attending positions <= base_len + j
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (1, q.shape[1], 1), 1) // rep
    for j in range(pps):
        if quant:
            k = kv_dequantize(k_refs[j][0], ks_refs[j][0][..., None],
                              jnp.float32)             # (hkv, page, d)
            v = kv_dequantize(v_refs[j][0], vs_refs[j][0][..., None],
                              jnp.float32)
        else:
            k = k_refs[j][0].astype(jnp.float32)
            v = v_refs[j][0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,)))) * scale
        k_pos = (ip * pps + j) * page + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page), 2)
        s = jnp.where(k_pos <= base_len + q_idx, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[..., None]
                        + jax.lax.dot_general(p, v,
                                              (((2,), (1,)), ((0,), (0,)))))
        m_ref[...] = m_new

    @pl.when(ip == np_ - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_paged_verify_tpu(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           k_scale=None, v_scale=None,
                           pages_per_step=None,
                           interpret: bool = True) -> jax.Array:
    """q: (B, K, H, D) — K new tokens per row, whose KV is already in the
    pool at positions ``lengths[b] .. lengths[b]+K-1``; pools:
    (P, page, Hkv, D); block_tables: (B, maxp) int32; lengths: (B,) int32
    valid tokens per row BEFORE the K new tokens.  For int8 pools pass
    ``k_scale``/``v_scale``: (P, page, Hkv, 1) per-token-per-head scales.
    ``pages_per_step`` overrides the recorded tuning.  Returns (B, K, H, D).
    """
    kq = q.shape[1]
    return _paged_attention(q, k_pool, v_pool, block_tables, lengths,
                            k_scale, v_scale, pages_per_step, interpret,
                            _verify_kernel, kq=kq)
