"""A small batched serving engine — the node's Model Manager backend.

Real (not simulated) JAX inference with **slot-based continuous batching**
(DESIGN.md §6.1): the engine keeps a persistent decode cache with
``max_batch`` row slots, each resident sequence decoding at its own depth
(per-row cache lengths).  After every decode step finished sequences are
evicted and queued requests are prefilled into the freed slots — a short
request no longer holds the batch hostage for the longest request's budget.
Prompts are right-padded, which causal attention keeps inert, so a request's
greedy output is independent of what it happens to be batched with (wave
batching, ``continuous=False``, produces bit-identical greedy results in
more decode steps).

``Engine(paged=True)`` swaps the per-slot contiguous cache for a **paged KV
cache** (DESIGN.md §6.1, paged backend): a fixed pool of page-sized KV
blocks with a per-sequence block table, grown one page at a time during
decode.  Admission charges a request's *prompt* pages only (not
``prompt + max_new`` as the contiguous slot cache must reserve), finished
sequences return their pages to the pool, and when the pool exhausts
mid-decode the most recently admitted sequence is preempted — its pages
reclaimed, its request requeued at the head of the queue for a greedy-
deterministic restart.  Greedy outputs stay bit-identical to the slot and
wave paths while strictly more requests are resident on the same KV budget.

``Engine(spec_draft=(draft_cfg, draft_params), spec_k=k)`` layers
**speculative decoding** (DESIGN.md §6.1-spec) on top of the paged backend:
a small same-tokenizer draft model proposes ``k`` tokens greedily, the
target verifies all of them in ONE batched multi-token forward
(``Family.paged_verify``), and the longest prefix of drafts matching the
target's own greedy choices is accepted — plus the target's correction
token, carried as next-step logits.  KV pages are claimed for accepted
tokens only (rejected drafts' writes sit beyond the valid length and are
overwritten).  Greedy outputs stay bit-identical to the non-speculative
paged engine: every emitted token is the argmax of the target's logits
over the same prefix, speculation only changes how many target forwards
that takes.

This is the backend used by the runnable examples and the end-to-end
decentralized serving driver (``repro.launch.serve``, via
``repro.serving.executor.EngineExecutor``); the large-scale scheduling
benchmarks use the simulated executor instead (see DESIGN.md §6.1).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.config import ModelConfig
from repro.serving.sampling import sample
from repro.sim.executor import paged_admit_ok, pages_for, quantized_pages
from repro.sim.servicemodel import SPEC_ALPHA0, SPEC_EMA_BETA, SPEC_K


def _greedy_tokens(logits: "jax.Array", vocab_size: int) -> "jax.Array":
    """Greedy token at every position of ``logits`` (..., V), with padded
    vocab entries masked — the same masking + argmax as the temperature-0
    path of :func:`repro.serving.sampling.sample`, so speculative
    verification reproduces non-speculative greedy choices exactly."""
    lg = logits.astype(jnp.float32)
    if vocab_size < lg.shape[-1]:
        pad_mask = jnp.arange(lg.shape[-1]) >= vocab_size
        lg = jnp.where(pad_mask, -1e30, lg)
    return jnp.argmax(lg, axis=-1).astype(jnp.int32)


@dataclass
class GenRequest:
    rid: str
    tokens: np.ndarray            # (S,) prompt token ids
    max_new: int = 32
    temperature: float = 0.0
    result: Optional[np.ndarray] = None
    # engine metrics (wall-clock)
    enqueued_at: float = 0.0
    started_at: float = 0.0       # admitted into a slot (prefill)
    first_token_at: float = 0.0   # first output token sampled
    finished_at: float = 0.0


@dataclass
class EngineStats:
    served: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    batches: int = 0              # prefill batches
    decode_steps: int = 0         # batched decode_step invocations
    prefill_wall_s: float = 0.0   # wall time inside prefill calls
    decode_wall_s: float = 0.0    # wall time inside decode_step calls
    peak_resident: int = 0        # max concurrently resident sequences
    preempted: int = 0            # paged: preempt-and-requeue events
    handoffs: int = 0             # disagg: KV handoffs extracted/accepted
    handoff_bytes: int = 0        # disagg: valid KV bytes handed off
    # speculative decoding (DESIGN.md §6.1-spec).  decode_tokens counts
    # EMITTED tokens and decode_wall_s the target-side verify walls, so
    # decode_tokens / decode_wall_s is the effective target decode
    # throughput; the draft's own cost is tracked in draft_wall_s.
    spec_steps: int = 0           # verify forwards (each checks spec_k drafts)
    spec_drafted: int = 0         # draft tokens proposed
    spec_accepted: int = 0        # draft tokens matching the target's greedy
    draft_wall_s: float = 0.0     # wall time inside draft prefill/decode jits
    verify_wall_s: float = 0.0    # wall time inside the verify jit


@dataclass
class KVHandoff:
    """A prefilled request leaving a disaggregated prefill engine
    (DESIGN.md §6.1-disagg): its populated KV pages, the tokens it has
    already sampled (the prefill side emits the first token), and the
    next-token logits the decode side resumes from.  ``k``/``v`` are
    page-granular copies — the prefill engine's physical pages are released
    the moment the handoff is extracted; the decode engine scatters them
    into its own pool under fresh page numbers (``Engine.accept_handoff``).
    """

    req: GenRequest
    out: List[int]                # tokens sampled on the prefill side (>= 1)
    length: int                   # valid KV tokens: prompt + len(out)
    k: "jax.Array"                # (L, n_pages, page, Hkv, dh)
    v: "jax.Array"
    logits: "jax.Array"           # (1, V) next-token logits
    page_size: int

    @property
    def kv_bytes(self) -> int:
        """Bytes of *valid* KV crossing the wire — the sim's transfer cost
        model charges the same quantity (prompt-dominated: len(out) is 1
        unless the prefill side raced ahead)."""
        n_layers, _, _, n_kv, dh = self.k.shape
        return 2 * n_layers * self.length * n_kv * dh * self.k.dtype.itemsize


class _Slot:
    """One resident sequence: its request, sampled tokens, cache depth."""

    __slots__ = ("req", "out")

    def __init__(self, req: GenRequest) -> None:
        self.req = req
        self.out: List[int] = []


class Engine:
    """Persistent-slot continuous batching with a jitted step per bucket."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 bucket: int = 64, seed: int = 0,
                 capacity: Optional[int] = None,
                 continuous: bool = True,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 spec_draft: Optional[Tuple[ModelConfig, Dict]] = None,
                 spec_k: int = SPEC_K) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.continuous = continuous
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        fam = registry.get_family(cfg)
        # right-padding is only inert with a full cache: a sliding-window
        # ring keeps the last `window` positions of the PADDED sequence, so
        # trailing pads would evict real in-window KV — window configs stay
        # on the left-padded lock-step wave path
        self.slot_decode = fam.slot_decode and cfg.sliding_window is None
        if self.slot_decode:
            self._prefill = jax.jit(
                lambda p, b, cap, lp: fam.prefill(p, cfg, b, q_chunk=256,
                                                  kv_chunk=256, capacity=cap,
                                                  last_positions=lp),
                static_argnums=(2,))
        else:
            # families without per-row cache depths fall back to left-padded
            # lock-step wave batching
            self._prefill = jax.jit(
                lambda p, b, cap: fam.prefill(p, cfg, b, q_chunk=256,
                                              kv_chunk=256, capacity=cap),
                static_argnums=(2,))
        self._decode = jax.jit(lambda p, c, t: fam.decode_step(p, cfg, c, t))
        self.eos_id = cfg.eos_id

        # persistent slot state
        self._queue: List[GenRequest] = []
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._lengths = np.zeros(max_batch, np.int64)   # per-row cache depth
        self._cache: Optional[Dict] = None
        self._logits: Optional[jax.Array] = None
        self._capacity = int(capacity or 0)

        # paged-KV state (DESIGN.md §6.1, paged backend)
        self.paged = bool(paged)
        self.page_size = int(page_size)
        if self.paged:
            if not (self.slot_decode and fam.paged_decode is not None):
                raise ValueError(
                    "paged KV requires a paged-capable slot-decode family "
                    "(dense/vlm with full attention)")
            # the decode/verify caches are DONATED: with the pools carried
            # through the layer scan (dense.paged_decode_step), donation
            # makes the page scatter a true in-place update, so step cost
            # is independent of pool size (§Perf-kernels).  Never reuse a
            # cache array after passing it in — the engine always reads the
            # returned cache.
            self._decode_paged = jax.jit(
                lambda p, c, t: fam.paged_decode(p, cfg, c, t),
                donate_argnums=(1,))
            self._scatter_pages = jax.jit(fam.prefill_to_pages,
                                          donate_argnums=(0,))
            self._init_pools = fam.init_paged_pools
            usable = (int(num_pages) if num_pages is not None
                      else max_batch * pages_for(2 * bucket, self.page_size))
            # int8 KV pages: the same HBM budget holds 2x the pages — the
            # shared sim/engine capacity rule (DESIGN.md §6.1-paged)
            usable = quantized_pages(usable, cfg.kv_quant)
            self._num_pages = usable + 1          # page 0 is scratch
            self._pools: Optional[Dict] = None    # lazy device alloc
            self._pool_names = (("k_pool", "v_pool", "k_scale_pool",
                                 "v_scale_pool") if cfg.kv_quant
                                else ("k_pool", "v_pool"))
            self._free_pages: List[int] = list(range(1, self._num_pages))
            self._row_pages: List[List[int]] = [[] for _ in range(max_batch)]
            self._maxp = max(1, pages_for(2 * bucket, self.page_size))
            self._block_tables = np.zeros((max_batch, self._maxp), np.int32)
            # device-resident block table + lengths (§Perf-kernels): the
            # decode cache passes both through, so steady-state decode skips
            # the per-step host->device upload; any host-side mutation
            # (admission, release, page claim) marks them dirty
            self._bt_dev: Optional[jax.Array] = None
            self._len_dev: Optional[jax.Array] = None
            self._tables_dirty = True
            # admission order, for LIFO preemption under pool pressure
            self._slot_seq = np.zeros(max_batch, np.int64)
            self._admit_seq = 0

        # speculative decoding (DESIGN.md §6.1-spec)
        self.spec = spec_draft is not None
        self.spec_k = int(spec_k) if self.spec else 0
        if self.spec:
            if not self.paged:
                raise ValueError("speculative decoding requires paged=True "
                                 "(the verify step targets the page pools)")
            if fam.paged_verify is None:
                raise ValueError("family has no paged_verify capability")
            if self.spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            draft_cfg, draft_params = spec_draft
            dfam = registry.get_family(draft_cfg)
            if not (dfam.slot_decode and draft_cfg.sliding_window is None):
                raise ValueError("draft model must support slot decode "
                                 "with full attention")
            if (draft_cfg.vocab_size != cfg.vocab_size
                    or draft_cfg.eos_id != cfg.eos_id):
                raise ValueError("draft and target must share the tokenizer "
                                 "(vocab_size / eos_id)")
            self.spec_draft_cfg = draft_cfg
            self.spec_draft_params = draft_params
            self._verify = jax.jit(
                lambda p, c, t: fam.paged_verify(p, cfg, c, t),
                donate_argnums=(1,))
            self._draft_prefill = jax.jit(
                lambda p, b, cap, lp: dfam.prefill(p, draft_cfg, b,
                                                   q_chunk=256, kv_chunk=256,
                                                   capacity=cap,
                                                   last_positions=lp),
                static_argnums=(2,))
            self._draft_decode = jax.jit(
                lambda p, c, t: dfam.decode_step(p, draft_cfg, c, t))
            # draft slot cache: contiguous per-row-depth KV, mirrored to the
            # target's slots (re-prefilled from scratch after preemption)
            self._draft_cache: Optional[Dict] = None
            self._draft_lengths = np.zeros(max_batch, np.int64)
            self._draft_capacity = 0
            # online per-token acceptance-rate EMA, seeded from the same sim
            # constant the SpecTokenBucketExecutor defaults to, so sim and
            # engine agree until real observations move it
            self.spec_alpha = SPEC_ALPHA0
            # accepted-length distribution: spec_accept_hist[a] counts
            # verify steps that accepted exactly a of spec_k drafts
            self.spec_accept_hist = [0] * (self.spec_k + 1)

    def _pad_bucket(self, n: int) -> int:
        b = self.bucket
        return max(b, (n + b - 1) // b * b)

    def _required(self, r: GenRequest) -> int:
        """Worst-case cache tokens a request may touch.  A speculative
        verify writes up to ``spec_k`` positions past the pending token, so
        the spec engine's worst case extends past pad(prompt)+pad(max_new)
        by the draft depth (rejected drafts' writes still need a mapped
        page, even though they never become valid tokens)."""
        extra = self.spec_k if self.spec else 0
        return (self._pad_bucket(len(r.tokens))
                + self._pad_bucket(r.max_new) + extra)

    def _draft_required(self, r: GenRequest) -> int:
        """Draft-cache capacity for ``r``: the page-rounded prefill width
        (the draft prefills the same right-padded prompt batch as the
        target) plus room to decode the pending token and ``spec_k``
        drafts at positions up to ``prompt + max_new - 2 + spec_k``."""
        plen = (-(-self._pad_bucket(len(r.tokens)) // self.page_size)
                * self.page_size)
        return plen + self._pad_bucket(r.max_new + self.spec_k)

    # ------------------------------------------------------------- interface
    def submit(self, r: GenRequest) -> None:
        if self.spec and r.temperature > 0.0:
            raise ValueError(
                "the speculative engine is greedy-only: draft acceptance "
                "compares argmax choices (temperature sampling would need "
                "rejection sampling, which breaks the bit-parity invariant)")
        r.enqueued_at = time.perf_counter()
        self._queue.append(r)

    def requeue(self, r: GenRequest) -> None:
        """Put a preempted/rerouted request back at the head of the queue
        WITHOUT re-stamping ``enqueued_at`` — its queue wait keeps counting
        from the original submission, so ``queue_wait`` stays monotone
        across preemption round-trips (the disagg executor routes
        decode-side preemptions back through the prefill engine)."""
        self._queue.insert(0, r)

    def take_queued(self) -> List[GenRequest]:
        """Drain and return the queue (admission re-routing: the disagg
        executor uses this to pull decode-side preemptions back out, since
        handoffs never travel through the decode engine's own queue)."""
        q, self._queue = self._queue, []
        return q

    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def active_slots(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    def queued(self) -> int:
        return len(self._queue)

    def load_snapshot(self) -> Dict[str, int]:
        """Occupancy counts for Executor.load() — the supported view of the
        slot/queue/page-pool bookkeeping (token counts are *remaining* work;
        this dict, not the private pool state, is the sanctioned external
        view — a grep-guard in tests/test_compat.py enforces it)."""
        active = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        snap = dict(
            active_streams=len(active),
            queued_streams=len(self._queue),
            queued_prompt_tokens=sum(len(r.tokens) for r in self._queue),
            queued_new_tokens=sum(r.max_new for r in self._queue),
            pending_decode_tokens=sum(s.req.max_new - len(s.out)
                                      for _, s in active),
            pages_used=0, pages_total=0, free_pages=0, page_size=0)
        if self.paged:
            usable = self._num_pages - 1
            used = usable - len(self._free_pages)
            snap.update(
                pages_used=used, pages_total=usable,
                free_pages=len(self._free_pages), page_size=self.page_size,
                # paged KV charges pages actually held, not reservations
                kv_used=used * self.page_size,
                kv_budget=usable * self.page_size)
        else:
            snap.update(
                kv_used=int(sum(self._lengths[i] + s.req.max_new - len(s.out)
                                for i, s in active)),
                kv_budget=self.max_batch * max(self._capacity, 1))
        return snap

    def serve(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Submit ``reqs`` and pump steps until the engine drains."""
        if not self.slot_decode:
            return self._serve_wave_legacy(reqs)
        for r in reqs:
            self.submit(r)
        while self.has_work():
            self.step()
        return reqs

    def generate_batch(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Serve up to max_batch requests together; returns them completed."""
        assert len(reqs) <= self.max_batch
        return self.serve(reqs)

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        if self.paged:
            self._admit_paged()
            return
        if not self._queue:
            return
        resident = any(s is not None for s in self._slots)
        if not self.continuous and resident:
            return                     # wave batching: refill only when empty
        if resident and any(self._required(r) > self._capacity
                            for r in self._queue):
            # a queued request needs a bigger cache, which can only be
            # allocated while nothing is resident: stop backfilling so the
            # batch drains and the growth branch below runs (otherwise a
            # steady stream of small requests starves the big one forever)
            return
        if not resident:
            # grow the cache while nothing is resident (allocation is static
            # under jit, so capacity only changes between generations)
            needed = max(self._required(r)
                         for r in self._queue[:self.max_batch])
            if self._cache is None or needed > self._capacity:
                self._capacity = max(self._capacity, needed)
                self._cache = None
                self._logits = None
        free = [i for i, s in enumerate(self._slots) if s is None]
        take: List[Tuple[int, GenRequest]] = []
        rest: List[GenRequest] = []
        for r in self._queue:
            # skip requests the current cache can't hold; they are admitted
            # at the next idle point, when capacity can grow
            if free and self._required(r) <= self._capacity:
                take.append((free.pop(0), r))
            else:
                rest.append(r)
        self._queue = rest
        if take:
            self._prefill_into(take)

    def _prefill_into(self, take: List[Tuple[int, GenRequest]]) -> None:
        n = len(take)
        plen = self._pad_bucket(max(len(r.tokens) for _, r in take))
        toks = np.full((n, plen), self.eos_id, np.int32)
        last = np.zeros(n, np.int32)
        for j, (_, r) in enumerate(take):
            toks[j, : len(r.tokens)] = r.tokens      # right-pad (inert)
            last[j] = len(r.tokens) - 1
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                      self._capacity, jnp.asarray(last))
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        self.stats.prefill_tokens += plen * n
        self.stats.batches += 1
        kv = {k: v for k, v in cache.items() if k != "length"}
        rows = jnp.asarray([i for i, _ in take])
        if self._cache is None:
            self._cache = jax.tree_util.tree_map(
                lambda leaf: jnp.zeros(
                    (leaf.shape[0], self.max_batch) + leaf.shape[2:],
                    leaf.dtype), kv)
            self._logits = jnp.zeros((self.max_batch, 1, logits.shape[-1]),
                                     logits.dtype)
        self._cache = jax.tree_util.tree_map(
            lambda p, nw: p.at[:, rows].set(nw), self._cache, kv)
        self._logits = self._logits.at[rows].set(logits)
        now = time.perf_counter()
        for i, r in take:
            r.started_at = now
            self._slots[i] = _Slot(r)
            self._lengths[i] = len(r.tokens)
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())

    # -------------------------------------------------------- paged admission
    def _pages(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def _admit_paged(self) -> None:
        if not self._queue:
            return
        resident = any(s is not None for s in self._slots)
        if not self.continuous and resident:
            return                     # wave batching: refill only when empty
        usable = self._num_pages - 1
        if resident and any(self._pages(self._required(r)) > usable
                            or (self.spec and self._draft_required(r)
                                > self._draft_capacity)
                            for r in self._queue):
            # a queued request cannot fit the pool (or the draft cache) even
            # alone; stop backfilling so the batch drains and the growth
            # branch runs
            return
        if not resident:
            # grow the pool while nothing is resident, so any single admitted
            # request can always run to completion (its worst-case pages fit
            # the pool) — this is what makes LIFO preemption livelock-free
            needed = max(self._pages(self._required(r))
                         for r in self._queue[:self.max_batch])
            if self._pools is None or needed > usable:
                self._num_pages = max(self._num_pages, needed + 1)
                usable = self._num_pages - 1
                self._pools = None
                self._logits = None
                self._free_pages = list(range(1, self._num_pages))
            if self.spec:
                # the draft cache is allocation-static under jit too: grow
                # it at the same idle points as the pool
                dneeded = max(self._draft_required(r)
                              for r in self._queue[:self.max_batch])
                if self._draft_cache is None \
                        or dneeded > self._draft_capacity:
                    self._draft_capacity = max(self._draft_capacity, dneeded)
                    self._draft_cache = None
        free_slots = [i for i, s in enumerate(self._slots) if s is None]
        free_now = len(self._free_pages)
        take: List[Tuple[int, GenRequest]] = []
        rest: List[GenRequest] = []
        taking = resident
        for r in self._queue:
            need = self._pages(len(r.tokens))
            if (free_slots and need <= free_now
                    and self._pages(self._required(r)) <= usable
                    and (not self.spec
                         or self._draft_required(r) <= self._draft_capacity)
                    and paged_admit_ok(free_now, len(r.tokens),
                                       self.page_size, resident=taking)):
                take.append((free_slots.pop(0), r))
                free_now -= need
                taking = True
            else:
                rest.append(r)
        self._queue = rest
        if take:
            self._grow_block_tables(max(self._pages(self._required(r))
                                        for _, r in take))
            self._prefill_paged(take)

    def _grow_block_tables(self, maxp: int) -> None:
        if maxp <= self._maxp:
            return
        wider = np.zeros((self.max_batch, maxp), np.int32)
        wider[:, : self._maxp] = self._block_tables
        self._block_tables = wider
        self._maxp = maxp
        self._tables_dirty = True

    def _table_width(self, lookahead: int = 1) -> int:
        """Logical-page width the decode block table needs this step: every
        resident row's allocated pages, plus one column PAST the page its
        next ``lookahead`` writes land in.  The extra column matters for
        riding-along rows whose prompt exactly fills their pages: their
        inert write targets the next (unallocated) logical page, and
        without the column the clamped table lookup would alias slot 0 of
        their own last real page.  Rounded up to a power of two (few jit
        shapes), capped at the full table."""
        need = 1
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            last_write = (int(self._lengths[i]) + lookahead - 1)
            need = max(need, len(self._row_pages[i]),
                       last_write // self.page_size + 1)
        w = 1
        while w < need:
            w *= 2
        return min(w, self._maxp)

    def _prefill_paged(self, take: List[Tuple[int, GenRequest]]) -> None:
        """Right-padded prompt prefill, then scatter the contiguous KV into
        freshly allocated pool pages (pad-tail pages alias the scratch page
        0, which per-row lengths keep inert)."""
        n = len(take)
        plen = self._pad_bucket(max(len(r.tokens) for _, r in take))
        plen = -(-plen // self.page_size) * self.page_size  # page multiple
        toks = np.full((n, plen), self.eos_id, np.int32)
        last = np.zeros(n, np.int32)
        phys = np.zeros((n, plen // self.page_size), np.int32)
        for j, (i, r) in enumerate(take):
            toks[j, : len(r.tokens)] = r.tokens      # right-pad (inert)
            last[j] = len(r.tokens) - 1
            pages = [self._free_pages.pop() for _ in
                     range(self._pages(len(r.tokens)))]
            self._row_pages[i] = pages
            phys[j, : len(pages)] = pages
            self._block_tables[i, :] = 0
            self._block_tables[i, : len(pages)] = pages
            self._slots[i] = _Slot(r)
            self._lengths[i] = len(r.tokens)
            self._slot_seq[i] = self._admit_seq
            self._admit_seq += 1
        self._tables_dirty = True
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                      plen, jnp.asarray(last))
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        now = time.perf_counter()       # started_at matches the slot path:
        for _, r in take:               # stamped after prefill completes
            r.started_at = now
        self.stats.prefill_tokens += plen * n
        self.stats.batches += 1
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())
        kv = {k: v for k, v in cache.items() if k != "length"}
        if self._pools is None:
            self._pools = self._init_pools(self.cfg, self._num_pages,
                                           self.page_size)
            self._logits = jnp.zeros((self.max_batch, 1, logits.shape[-1]),
                                     logits.dtype)
        self._pools = self._scatter_pages(self._pools, kv, jnp.asarray(phys))
        rows = jnp.asarray([i for i, _ in take])
        self._logits = self._logits.at[rows].set(logits)
        if self.spec:
            self._spec_prefill_draft(take, toks, last)

    def _spec_prefill_draft(self, take: List[Tuple[int, GenRequest]],
                            toks: np.ndarray, last: np.ndarray) -> None:
        """Run the draft model's prefill over the same right-padded prompts
        and install its contiguous KV rows next to the target's slots
        (DESIGN.md §6.1-spec).  The draft's prompt logits are discarded:
        drafting always starts by feeding the pending token."""
        t0 = time.perf_counter()
        dlogits, dcache = self._draft_prefill(
            self.spec_draft_params, {"tokens": jnp.asarray(toks)},
            self._draft_capacity, jnp.asarray(last))
        dlogits.block_until_ready()
        self.stats.draft_wall_s += time.perf_counter() - t0
        dkv = {k: v for k, v in dcache.items() if k != "length"}
        if self._draft_cache is None:
            self._draft_cache = jax.tree_util.tree_map(
                lambda leaf: jnp.zeros(
                    (leaf.shape[0], self.max_batch) + leaf.shape[2:],
                    leaf.dtype), dkv)
        rows = jnp.asarray([i for i, _ in take])
        self._draft_cache = jax.tree_util.tree_map(
            lambda p, nw: p.at[:, rows].set(nw), self._draft_cache, dkv)
        for i, r in take:
            self._draft_lengths[i] = len(r.tokens)

    # ----------------------------------------------------- page pool dynamics
    def _release_pages(self, i: int) -> None:
        self._free_pages.extend(self._row_pages[i])
        self._row_pages[i] = []
        self._block_tables[i, :] = 0
        self._tables_dirty = True

    def _preempt(self, i: int) -> None:
        """Reclaim row ``i``'s pages and requeue its request at the head of
        the queue (vLLM-style recompute preemption: generated tokens are
        discarded; the greedy restart reproduces them bit-identically).

        The admission clocks are reset along with the discarded tokens:
        ``started_at``/``first_token_at`` belong to the aborted attempt, so
        leaving them set would let a mid-flight reader (metrics scrape, the
        disagg executor re-routing the request) report a TTFT for tokens
        the user never kept.  The restart re-stamps both, which also keeps
        ``enqueued_at <= started_at <= first_token_at <= finished_at``
        monotone on the completion record."""
        r = self._slots[i].req
        r.result = None
        r.started_at = 0.0
        r.first_token_at = 0.0
        self._release_pages(i)
        self._slots[i] = None
        self._lengths[i] = 0
        if self.spec:
            # the draft row is re-prefilled from scratch on re-admission
            self._draft_lengths[i] = 0
        self._queue.insert(0, r)
        self.stats.preempted += 1

    def _ensure_decode_pages(self, survivors: List[int],
                             lookahead: int = 1) -> List[int]:
        """Allocate pages covering the next ``lookahead`` write positions
        for every surviving row (1 for plain decode; ``spec_k + 1`` for a
        speculative verify, which writes the pending token plus k drafts).
        Under pool pressure the most recently admitted resident is
        preempted until a page frees; oldest rows are served first, so the
        oldest admission always makes progress and the preemption loop
        terminates."""
        for i in sorted(survivors, key=lambda i: self._slot_seq[i]):
            while (self._slots[i] is not None
                   and (self._lengths[i] + lookahead - 1) // self.page_size
                   >= len(self._row_pages[i])):
                if self._free_pages:
                    pg = self._free_pages.pop()
                    self._row_pages[i].append(pg)
                    idx = len(self._row_pages[i]) - 1
                    self._grow_block_tables(idx + 1)
                    self._block_tables[i, idx] = pg
                    self._tables_dirty = True
                else:
                    victims = [j for j, s in enumerate(self._slots)
                               if s is not None]
                    self._preempt(max(victims, key=lambda j:
                                      self._slot_seq[j]))
        return [i for i in survivors if self._slots[i] is not None]

    # ------------------------------------------- disaggregated KV handoff
    # (DESIGN.md §6.1-disagg) — both ends live here because the page pool,
    # block tables, and free list are private to the engine (grep-guarded).

    def extract_handoffs(self) -> List[KVHandoff]:
        """Disagg prefill side: pop every resident row that has sampled at
        least one token as a ``KVHandoff`` and release its local pages.

        Driven after each ``step()`` of a prefill-role engine: a freshly
        admitted row samples its first token and decodes it (writing its KV)
        within that same step, so no row ever survives two steps here — the
        prefill engine's pool only ever holds prompts mid-prefill.  The
        gathered ``k``/``v`` are copies, which is what the simulated
        transfer cost model charges for.
        """
        assert self.paged, "KV handoff requires the paged backend"
        assert not self.spec, "KV handoff and speculative decoding are " \
            "separate backends (the draft cache does not travel)"
        assert not self.cfg.kv_quant, "KV handoff carries fp pages only " \
            "(quantized scale pools do not travel; DESIGN.md §6.1-paged)"
        out: List[KVHandoff] = []
        for i, s in enumerate(self._slots):
            if s is None or not s.out:
                continue
            pages = jnp.asarray(self._row_pages[i], jnp.int32)
            h = KVHandoff(
                req=s.req, out=list(s.out), length=int(self._lengths[i]),
                k=self._pools["k_pool"][:, pages],
                v=self._pools["v_pool"][:, pages],
                logits=self._logits[i], page_size=self.page_size)
            self._release_pages(i)
            self._slots[i] = None
            self._lengths[i] = 0
            self.stats.handoffs += 1
            self.stats.handoff_bytes += h.kv_bytes
            out.append(h)
        return out

    def accept_handoff(self, h: KVHandoff) -> bool:
        """Disagg decode side: allocate pages for a handed-off request,
        scatter its KV into this engine's pool, and install it in a free
        slot with its prefill logits — decode resumes exactly where the
        prefill engine stopped, so greedy outputs stay bit-identical to a
        colocated paged engine.  Returns False (caller retries after a
        completion) when no slot or not enough free pages are available.
        """
        assert self.paged and h.page_size == self.page_size
        assert not self.spec, "KV handoff and speculative decoding are " \
            "separate backends (the draft cache does not travel)"
        assert not self.cfg.kv_quant, "KV handoff carries fp pages only " \
            "(quantized scale pools do not travel; DESIGN.md §6.1-paged)"
        free_slots = [i for i, s in enumerate(self._slots) if s is None]
        if not free_slots:
            return False
        resident = any(s is not None for s in self._slots)
        usable = self._num_pages - 1
        worst = self._pages(self._required(h.req))
        if not resident:
            # grow the pool while nothing is resident (mirror _admit_paged)
            # so any single accepted handoff can always run to completion
            if self._pools is None or worst > usable:
                self._num_pages = max(self._num_pages, worst + 1)
                usable = self._num_pages - 1
                self._pools = None
                self._logits = None
                self._free_pages = list(range(1, self._num_pages))
        elif worst > usable:
            return False               # can never fit: wait for drain+growth
        need = pages_for(h.length, self.page_size)
        if need > len(self._free_pages):
            return False
        if self._pools is None:
            self._pools = self._init_pools(self.cfg, self._num_pages,
                                           self.page_size)
            self._logits = jnp.zeros(
                (self.max_batch, 1, h.logits.shape[-1]), h.logits.dtype)
        i = free_slots[0]
        pages = [self._free_pages.pop() for _ in range(need)]
        phys = jnp.asarray(pages, jnp.int32)
        self._pools = {
            "k_pool": self._pools["k_pool"].at[:, phys].set(h.k[:, :need]),
            "v_pool": self._pools["v_pool"].at[:, phys].set(h.v[:, :need])}
        self._grow_block_tables(max(need, worst))
        self._row_pages[i] = pages
        self._block_tables[i, :] = 0
        self._block_tables[i, :need] = pages
        self._tables_dirty = True
        slot = _Slot(h.req)
        slot.out = list(h.out)
        self._slots[i] = slot
        self._lengths[i] = h.length
        self._slot_seq[i] = self._admit_seq
        self._admit_seq += 1
        self._logits = self._logits.at[i].set(h.logits)
        self.stats.handoffs += 1
        self.stats.handoff_bytes += h.kv_bytes
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())
        return True

    # ------------------------------------------------------------ decode step
    def _append_token(self, i: int, t: int, now: float,
                      finished: List[GenRequest]) -> bool:
        """Append one emitted token to row ``i``, retiring the row on EOS
        or budget exhaustion (shared by the plain sampling phase and the
        speculative acceptance loop, so multi-token emission keeps the
        exact single-token semantics: EOS is dropped from the result
        unless it is the only token).  Returns True while the row
        survives."""
        slot = self._slots[i]
        slot.out.append(t)
        if len(slot.out) == 1:
            slot.req.first_token_at = now
        hit_eos = t == self.eos_id
        if hit_eos or len(slot.out) >= slot.req.max_new:
            row = slot.out[:-1] if hit_eos and len(slot.out) > 1 \
                else slot.out
            slot.req.result = np.asarray(row, np.int32)
            slot.req.finished_at = now
            finished.append(slot.req)
            self._slots[i] = None
            if self.paged:
                self._release_pages(i)         # pages return to the pool
            self.stats.served += 1
            return False
        return True

    def step(self) -> List[GenRequest]:
        """One engine iteration: sample a token for every resident sequence,
        retire finished ones, prefill admissions into freed slots, then run
        one batched decode step for the sequences that continue."""
        if not self.slot_decode:
            return self._step_wave_legacy()
        if self.spec:
            return self._step_spec()
        self._admit()
        resident = [i for i, s in enumerate(self._slots) if s is not None]
        if not resident:
            return []
        # 1. sample next token for all resident rows from their current logits
        self.key, sk = jax.random.split(self.key)
        temps_np = np.zeros(self.max_batch, np.float32)
        for i in resident:
            temps_np[i] = self._slots[i].req.temperature
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        cur = sample(sk, self._logits, temperature=temps,
                     vocab_size=self.cfg.vocab_size)
        cur_np = np.asarray(cur[:, 0])
        now = time.perf_counter()
        finished: List[GenRequest] = []
        survivors: List[int] = []
        for i in resident:
            if self._append_token(i, int(cur_np[i]), now, finished):
                survivors.append(i)
        # 2. admit queued work into freed slots between decode steps
        if self.continuous and finished:
            self._admit()
        # 2b. paged: claim this step's write page per survivor, preempting
        #     the most recent admissions if the pool is exhausted
        if self.paged and survivors:
            survivors = self._ensure_decode_pages(survivors)
        # 3. one batched decode step advances the surviving rows; rows that
        #    were empty or just prefilled ride along (static batch shape) —
        #    their cache write lands at their own depth and is overwritten by
        #    their first real decode, and their logits are kept, not replaced
        if survivors:
            t0 = time.perf_counter()
            if self.paged:
                # trim the table to the pages live rows can actually touch
                # and reuse the device-resident copy whenever no host-side
                # mutation invalidated it (§Perf-kernels)
                w = self._table_width()
                if (self._tables_dirty or self._bt_dev is None
                        or self._bt_dev.shape[1] != w):
                    self._bt_dev = jnp.asarray(self._block_tables[:, :w])
                    self._len_dev = jnp.asarray(self._lengths, jnp.int32)
                cache = {**self._pools, "block_tables": self._bt_dev,
                         "lengths": self._len_dev}
                logits, cache = self._decode_paged(self.params, cache, cur)
                logits.block_until_ready()
                self._pools = {n: cache[n] for n in self._pool_names}
                # the cache is donated: only the RETURNED tables/lengths are
                # valid now.  They advanced every row by one; reuse is only
                # sound when every active row was a survivor — a rider row
                # (admitted mid-step) holds its prompt length on the host
                # but length+1 on the device, so its next write would skip
                # a position.  Any rider forces a re-upload.
                self._bt_dev = cache["block_tables"]
                self._len_dev = cache["lengths"]
                self._tables_dirty = self.active_slots() != len(survivors)
            else:
                cache = {**self._cache,
                         "length": jnp.asarray(self._lengths, jnp.int32)}
                logits, cache = self._decode(self.params, cache, cur)
                logits.block_until_ready()
                self._cache = {k: v for k, v in cache.items()
                               if k != "length"}
            self.stats.decode_wall_s += time.perf_counter() - t0
            keep = jnp.asarray(survivors)
            self._logits = self._logits.at[keep].set(logits[keep])
            self._lengths[survivors] += 1
            self.stats.decode_tokens += len(survivors)
            self.stats.decode_steps += 1
        return finished

    # ------------------------------------------------- speculative decoding
    def _step_spec(self) -> List[GenRequest]:
        """One speculative engine iteration (DESIGN.md §6.1-spec).

        The pending token is sampled for every resident row from its
        carried logits exactly as the plain paged step does; then, instead
        of one single-token decode, the draft model proposes ``spec_k``
        tokens greedily and ONE batched target forward
        (``Family.paged_verify``) scores pending + drafts at once.  The
        longest draft prefix matching the target's own greedy choices is
        emitted; the correction token is NOT emitted here — the verify
        logits after the last accepted token become the carried logits, so
        the next iteration's sampling phase reproduces it.  Every emitted
        token is therefore the argmax of target logits over the same
        prefix as non-speculative decode: greedy outputs are
        bit-identical, speculation only changes how many target forwards
        they take.
        """
        self._admit()
        resident = [i for i, s in enumerate(self._slots) if s is not None]
        if not resident:
            return []
        # 1. pending token from carried logits (identical to the base step;
        #    spec rows are greedy-only, enforced at submit)
        self.key, sk = jax.random.split(self.key)
        cur = sample(sk, self._logits, temperature=0.0,
                     vocab_size=self.cfg.vocab_size)
        cur_np = np.asarray(cur[:, 0])
        now = time.perf_counter()
        finished: List[GenRequest] = []
        survivors: List[int] = []
        for i in resident:
            if self._append_token(i, int(cur_np[i]), now, finished):
                survivors.append(i)
        # 2. admit queued work into freed slots between steps (freshly
        #    prefilled rows ride along this verify and join the next one)
        if self.continuous and finished:
            self._admit()
        # 2b. claim pages covering the pending token + spec_k draft writes,
        #     preempting the most recent admissions if the pool exhausts
        if survivors:
            survivors = self._ensure_decode_pages(survivors,
                                                  lookahead=self.spec_k + 1)
        if not survivors:
            return finished
        k = self.spec_k
        # 3. draft k tokens greedily, feeding the pending token first; the
        #    draft cache rows advance in lock-step with the target's pages
        #    (riding-along rows write garbage at their own stale depth,
        #    fully overwritten before it is ever attended)
        drafts = np.zeros((self.max_batch, k), np.int32)
        tok = cur
        t0 = time.perf_counter()
        for j in range(k):
            dcache = {**self._draft_cache,
                      "length": jnp.asarray(self._draft_lengths + j,
                                            jnp.int32)}
            dlogits, dcache = self._draft_decode(self.spec_draft_params,
                                                 dcache, tok)
            dlogits.block_until_ready()
            self._draft_cache = {n: v for n, v in dcache.items()
                                 if n != "length"}
            tok = _greedy_tokens(dlogits[:, -1],
                                 self.spec_draft_cfg.vocab_size)[:, None]
            drafts[:, j] = np.asarray(tok[:, 0])
        # land the last draft's KV too: each proposing forward writes its
        # INPUT token, so d_k would be missing from the draft cache when
        # all k drafts are accepted and the next round builds on it — one
        # discarded forward writes it at draft position n + k (harmless
        # for rows that accept less: the position is past their valid
        # prefix and overwritten before it is ever attended)
        dcache = {**self._draft_cache,
                  "length": jnp.asarray(self._draft_lengths + k, jnp.int32)}
        dlogits, dcache = self._draft_decode(self.spec_draft_params,
                                             dcache, tok)
        dlogits.block_until_ready()
        self._draft_cache = {n: v for n, v in dcache.items()
                             if n != "length"}
        self.stats.draft_wall_s += time.perf_counter() - t0
        self.stats.spec_drafted += k * len(survivors)
        # 4. verify pending + drafts in ONE batched target forward; the
        #    verify scatters all k+1 tokens' KV into the pages claimed in
        #    2b (rejected drafts land beyond the valid length and are
        #    overwritten by the next verify at the same positions)
        toks = np.concatenate([cur_np[:, None], drafts], axis=1)
        # spec lengths advance by a variable 1+a per row, so the device
        # tables are rebuilt every verify (no resident reuse); the width is
        # still trimmed to the pages the k+1 writes can touch
        w = self._table_width(lookahead=self.spec_k + 1)
        cache = {**self._pools,
                 "block_tables": jnp.asarray(self._block_tables[:, :w]),
                 "lengths": jnp.asarray(self._lengths, jnp.int32)}
        t0 = time.perf_counter()
        vlogits, cache = self._verify(self.params, cache, jnp.asarray(toks))
        vlogits.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats.decode_wall_s += dt
        self.stats.verify_wall_s += dt
        self._pools = {n: cache[n] for n in self._pool_names}
        # the target's greedy choice at every position, with the same
        # vocab masking + argmax as sample(temperature=0)
        tgt = np.asarray(_greedy_tokens(vlogits, self.cfg.vocab_size))
        # 5. per row: accept the longest draft prefix matching the target,
        #    emit it under the usual EOS/budget rules, advance the caches
        #    over pending + accepted tokens only
        now = time.perf_counter()
        rows: List[int] = []
        pos: List[int] = []
        accepts: List[int] = []
        for i in survivors:
            a = 0
            while a < k and drafts[i, a] == tgt[i, a]:
                a += 1
            self.spec_accept_hist[a] += 1
            self.stats.spec_accepted += a
            accepts.append(a)
            appended = 0
            alive = True
            for j in range(a):
                appended += 1
                if not self._append_token(i, int(drafts[i, j]), now,
                                          finished):
                    alive = False
                    break
            # count tokens fed to a target forward as valid context — the
            # same rule the plain path's len(survivors) implements: a
            # request's FINAL emitted token (here: the draft that retired
            # the row) never feeds a forward, so both engines accumulate
            # identical decode_tokens for identical outputs
            self.stats.decode_tokens += appended + (1 if alive else 0)
            if alive:
                self._lengths[i] += 1 + a
                self._draft_lengths[i] = self._lengths[i]
                rows.append(i)
                pos.append(a)       # carry logits after the last accepted
        # ONE EMA update per verify step (the documented SPEC_EMA_BETA
        # semantics), over the step's mean acceptance — per-row updates
        # would scale the effective smoothing with batch size
        obs = sum(accepts) / (k * len(accepts))
        self.spec_alpha += SPEC_EMA_BETA * (obs - self.spec_alpha)
        # 6. carry each surviving row's correction logits: position a is the
        #    target's distribution after [pending, d_1..d_a] — next step's
        #    argmax emits the correction (or the bonus token when a == k)
        if rows:
            ridx = jnp.asarray(rows)
            upd = vlogits[ridx, jnp.asarray(pos)][:, None]
            self._logits = self._logits.at[ridx].set(upd)
        self.stats.decode_steps += 1
        self.stats.spec_steps += 1
        return finished

    # ----------------------------------------------- legacy wave (non-dense)
    def _step_wave_legacy(self) -> List[GenRequest]:
        if not self._queue:
            return []
        wave, self._queue = (self._queue[: self.max_batch],
                             self._queue[self.max_batch:])
        return self._generate_wave(wave)

    def _serve_wave_legacy(self, reqs: List[GenRequest]) -> List[GenRequest]:
        out: List[GenRequest] = []
        for i in range(0, len(reqs), self.max_batch):
            out.extend(self._generate_wave(reqs[i: i + self.max_batch]))
        return out

    def _generate_wave(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Left-padded lock-step decode for families without per-row cache
        depths (shared scalar cache length)."""
        assert len(reqs) <= self.max_batch
        max_prompt = max(len(r.tokens) for r in reqs)
        plen = self._pad_bucket(max_prompt)
        max_new = max(r.max_new for r in reqs)
        toks = np.full((len(reqs), plen), self.eos_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.tokens):] = r.tokens     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cap = plen + self._pad_bucket(max_new)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cap)
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        self.stats.prefill_tokens += plen * len(reqs)
        self.stats.batches += 1
        for r in reqs:
            r.started_at = time.perf_counter()

        out = np.zeros((len(reqs), max_new), np.int32)
        done = np.zeros(len(reqs), bool)
        temps_np = np.array([r.temperature for r in reqs], np.float32)
        # all-greedy batches (the default) keep the scalar fast path in
        # sample(), skipping the per-step Gumbel draw over the vocab
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        budgets = np.array([r.max_new for r in reqs])
        for step in range(max_new):
            self.key, sk = jax.random.split(self.key)
            cur = sample(sk, logits, temperature=temps,
                         vocab_size=self.cfg.vocab_size)
            out[:, step] = np.asarray(cur[:, 0])
            if step == 0:
                now = time.perf_counter()
                for r in reqs:
                    r.first_token_at = now
            done |= out[:, step] == self.eos_id
            done |= step + 1 >= budgets
            if done.all():
                break
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, cur)
            logits.block_until_ready()
            self.stats.decode_wall_s += time.perf_counter() - t0
            self.stats.decode_tokens += int((~done).sum())
            self.stats.decode_steps += 1
        for i, r in enumerate(reqs):
            row = out[i, : r.max_new]
            end = np.argmax(row == self.eos_id) if (row ==
                                                    self.eos_id).any() \
                else r.max_new
            r.result = row[: max(int(end), 1)]
            r.finished_at = time.perf_counter()
        self.stats.served += len(reqs)
        return reqs

    def logprob_of(self, tokens: np.ndarray) -> float:
        """Sequence log-likelihood under this engine's model — used by the
        real-engine duel judges (DESIGN.md §6.2)."""
        t = jnp.asarray(tokens[None, :])
        logits = registry.apply_logits(self.params, self.cfg,
                                       {"tokens": t[:, :-1]},
                                       q_chunk=256, kv_chunk=256)
        logits = logits.astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(lp, t[:, 1:, None], axis=-1)
        return float(jnp.sum(gold))
